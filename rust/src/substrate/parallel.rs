//! Std-only scoped thread pool for the substrate hot loops.
//!
//! Design constraints (see ROADMAP items 2–3):
//!
//! * **In-tree, std-only** — no rayon/crossbeam; a small persistent pool of
//!   workers fed through a condvar, with the submitting thread always
//!   participating in the work so `threads() == 1` never context-switches.
//! * **Deterministic** — disjoint-output loops (matmul row blocks, per-row
//!   FFTs) are bit-for-bit identical at any thread count because every
//!   element is computed by the same scalar code, regardless of how rows
//!   are grouped (those loops may size chunks off [`row_chunk`], which
//!   scales with the pool).  Reductions are stricter: they must use
//!   [`map_chunks`] with a *fixed* chunk size so the per-chunk partials —
//!   combined by the caller **in chunk order** — make the floating-point
//!   addition order thread-count independent too.
//! * **Never nested** — a parallel region entered from a pool worker (or
//!   while another region is active) runs inline on the calling thread.
//!
//! Thread count: `C3A_THREADS` env var if set (>=1), else
//! `std::thread::available_parallelism()`.  [`set_threads`] overrides at
//! runtime (used by the parity tests and the bench harness).

use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

// ---------------------------------------------------------------------------
// Thread-count configuration
// ---------------------------------------------------------------------------

fn default_threads() -> usize {
    if let Some(n) = crate::substrate::env::threads() {
        return n;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

fn threads_cell() -> &'static AtomicUsize {
    static CELL: OnceLock<AtomicUsize> = OnceLock::new();
    CELL.get_or_init(|| AtomicUsize::new(default_threads()))
}

/// Current worker budget (including the calling thread).
pub fn threads() -> usize {
    // Relaxed: an isolated config word — no other memory is published
    // through it, and a stale read only mis-sizes a chunk heuristic.
    threads_cell().load(Ordering::Relaxed)
}

/// Override the worker budget at runtime (clamped to >= 1).  Results are
/// bit-for-bit identical at any setting; this only trades wall-clock.
pub fn set_threads(n: usize) {
    // Relaxed: see `threads` — the value is self-contained config.
    threads_cell().store(n.max(1), Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// The pool
// ---------------------------------------------------------------------------

/// One queued parallel region: workers pull chunk indices from `counter`
/// and call `f(index)` until the range is exhausted.  The `'static`
/// lifetimes are a lie told via transmute; they hold in practice because
/// the submitting thread blocks until every worker has checked out of the
/// epoch, so the borrows outlive all uses.
#[derive(Clone, Copy)]
struct Job {
    f: &'static (dyn Fn(usize) + Sync),
    counter: *const AtomicUsize,
    n_chunks: usize,
    panicked: *const AtomicBool,
}

// SAFETY: the raw pointers target the submitting stack frame, which
// `run_chunked` keeps alive until every worker has checked out of the
// epoch (the done_cv handshake); `f` is additionally `Sync`.
unsafe impl Send for Job {}

struct PoolState {
    job: Option<Job>,
    /// bumped once per submitted job; workers track the last epoch seen
    epoch: u64,
    /// workers that have not yet checked out of the current epoch
    active: usize,
    /// spawned worker threads
    workers: usize,
}

struct Pool {
    state: Mutex<PoolState>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// serializes regions: one job in flight at a time.  Contended
    /// submissions run inline instead of queueing.
    submit: Mutex<()>,
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool {
        state: Mutex::new(PoolState { job: None, epoch: 0, active: 0, workers: 0 }),
        work_cv: Condvar::new(),
        done_cv: Condvar::new(),
        submit: Mutex::new(()),
    })
}

thread_local! {
    /// True on pool workers and inside an active region on the submitter:
    /// any nested region runs inline.
    static IN_REGION: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

fn worker_loop(p: &'static Pool) {
    IN_REGION.with(|f| f.set(true));
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = p.state.lock().unwrap();
            loop {
                if st.epoch != seen {
                    seen = st.epoch;
                    if let Some(j) = st.job {
                        break j;
                    }
                    // epoch advanced with no job: check out immediately
                    st.active -= 1;
                    if st.active == 0 {
                        p.done_cv.notify_all();
                    }
                    continue;
                }
                st = p.work_cv.wait(st).unwrap();
            }
        };
        let f = job.f;
        // SAFETY: both pointers stay valid for the whole epoch — the
        // submitter blocks on done_cv until this worker checks out below.
        let counter = unsafe { &*job.counter };
        // SAFETY: same lifetime argument as `counter` above.
        let panicked = unsafe { &*job.panicked };
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| loop {
            // Relaxed: the counter only hands out chunk indices; the
            // chunk data itself is published by the state-mutex fences.
            let i = counter.fetch_add(1, Ordering::Relaxed);
            // Relaxed: advisory early-exit flag — missing an update just
            // runs one more chunk before stopping.
            if i >= job.n_chunks || panicked.load(Ordering::Relaxed) {
                break;
            }
            f(i);
        }));
        if res.is_err() {
            // Relaxed: advisory flag (see the load above); the authoritative
            // panic propagation happens through the submitter's catch.
            panicked.store(true, Ordering::Relaxed);
        }
        let mut st = p.state.lock().unwrap();
        st.active -= 1;
        if st.active == 0 {
            p.done_cv.notify_all();
        }
    }
}

/// Run `f(0..n_chunks)` across the pool, submitter participating.  Falls
/// back to an inline ascending loop when parallelism is unavailable; the
/// chunk decomposition (and therefore the numerics of chunked reductions)
/// is identical either way.
fn run_chunked(n_chunks: usize, f: &(dyn Fn(usize) + Sync)) {
    let inline = n_chunks <= 1
        || threads() <= 1
        || IN_REGION.with(|flag| flag.get());
    if inline {
        for i in 0..n_chunks {
            f(i);
        }
        return;
    }
    let p = pool();
    let _guard = match p.submit.try_lock() {
        Ok(g) => g,
        // a previous region panicked mid-flight: the pool protocol itself
        // is still sound (the panicking submitter waited for checkout), so
        // recover the lock instead of degrading to inline forever
        Err(std::sync::TryLockError::Poisoned(e)) => e.into_inner(),
        // another thread owns the pool: run inline rather than queue
        Err(std::sync::TryLockError::WouldBlock) => {
            for i in 0..n_chunks {
                f(i);
            }
            return;
        }
    };
    let counter = AtomicUsize::new(0);
    let panicked = AtomicBool::new(false);
    {
        let mut st = p.state.lock().unwrap();
        // lazily grow the worker set toward threads() - 1
        let want = (threads() - 1).min(n_chunks.saturating_sub(1));
        while st.workers < want {
            std::thread::Builder::new()
                .name("c3a-pool".into())
                .spawn(move || worker_loop(pool()))
                .expect("spawning pool worker");
            st.workers += 1;
        }
        // SAFETY: erases the borrow lifetime only — the wait-for-checkout
        // below keeps `f`/`counter`/`panicked` alive past every worker
        // access, so no worker can observe the referent after it dies.
        let f_static: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute::<&(dyn Fn(usize) + Sync), _>(f) };
        st.job = Some(Job {
            f: f_static,
            counter: &counter as *const AtomicUsize,
            n_chunks,
            panicked: &panicked as *const AtomicBool,
        });
        st.epoch += 1;
        st.active = st.workers;
        p.work_cv.notify_all();
    }
    // participate from the submitting thread
    IN_REGION.with(|flag| flag.set(true));
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| loop {
        // Relaxed: chunk-index handout only (see worker_loop) — the
        // chunk results are published by the done_cv mutex handshake.
        let i = counter.fetch_add(1, Ordering::Relaxed);
        // Relaxed: advisory early-exit flag, same as the worker side.
        if i >= n_chunks || panicked.load(Ordering::Relaxed) {
            break;
        }
        f(i);
    }));
    IN_REGION.with(|flag| flag.set(false));
    if res.is_err() {
        // Relaxed: advisory — this thread rethrows its own panic below.
        panicked.store(true, Ordering::Relaxed);
    }
    // wait for every worker to check out before the closure/counter die
    {
        let mut st = p.state.lock().unwrap();
        while st.active > 0 {
            st = p.done_cv.wait(st).unwrap();
        }
        st.job = None;
    }
    if let Err(e) = res {
        std::panic::resume_unwind(e);
    }
    // Relaxed: every worker that could have stored checked out under the
    // state mutex above, so this read is ordered after all stores.
    if panicked.load(Ordering::Relaxed) {
        panic!("c3a-pool worker panicked");
    }
}

#[inline]
fn chunk_range(i: usize, chunk: usize, n: usize) -> Range<usize> {
    let start = i * chunk;
    start..n.min(start + chunk)
}

// ---------------------------------------------------------------------------
// Public combinators
// ---------------------------------------------------------------------------

/// Parallel-for over `n` items in fixed chunks of `chunk`: calls
/// `f(start..end)` for each chunk.  `f` must only touch disjoint state per
/// chunk (e.g. disjoint output rows); determinism then holds trivially.
pub fn for_each_chunk(n: usize, chunk: usize, f: impl Fn(Range<usize>) + Sync) {
    if n == 0 {
        return;
    }
    let chunk = chunk.max(1);
    let n_chunks = n.div_ceil(chunk);
    run_chunked(n_chunks, &|i| f(chunk_range(i, chunk, n)));
}

/// Chunked map for **deterministic reductions**: `f(start..end)` produces a
/// per-chunk partial; the returned Vec is in chunk order, so combining the
/// partials sequentially gives a floating-point result independent of the
/// thread count (the chunk boundaries depend only on `n` and `chunk`).
pub fn map_chunks<R: Send>(
    n: usize,
    chunk: usize,
    f: impl Fn(Range<usize>) -> R + Sync,
) -> Vec<R> {
    if n == 0 {
        return Vec::new();
    }
    let chunk = chunk.max(1);
    let n_chunks = n.div_ceil(chunk);
    let mut out: Vec<Option<R>> = (0..n_chunks).map(|_| None).collect();
    {
        let slots = SharedSlice::new(&mut out);
        run_chunked(n_chunks, &|i| {
            let r = f(chunk_range(i, chunk, n));
            // SAFETY: each chunk index writes exactly its own slot, and
            // the submitter outlives the region (SharedSlice contract).
            unsafe { *slots.get_mut(i) = Some(r) };
        });
    }
    out.into_iter().map(|s| s.expect("chunk slot filled")).collect()
}

/// Row-shard a disjoint-output buffer: `out` is `rows × row_width`
/// elements and `f(row_index, row)` computes one row.  When `parallel_ok`
/// (the caller's work-floor gate) and the pool has more than one thread,
/// rows are grouped into [`row_chunk`]-sized spans across the pool;
/// otherwise they run inline.  `f` must compute each row identically
/// regardless of grouping — this helper is for disjoint outputs only,
/// never reductions.
pub fn for_rows<T: Send>(
    out: &mut [T],
    row_width: usize,
    parallel_ok: bool,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    let row_width = row_width.max(1);
    let rows = out.len() / row_width;
    if parallel_ok && rows >= 2 && threads() > 1 {
        let chunk = row_chunk(rows, 1);
        par_chunks_mut(out, chunk * row_width, |ci, span| {
            let base = ci * chunk;
            for (ri, row) in span.chunks_mut(row_width).enumerate() {
                f(base + ri, row);
            }
        });
    } else {
        for (r, row) in out.chunks_mut(row_width).enumerate() {
            f(r, row);
        }
    }
}

/// Parallel mutation of disjoint `chunk_len`-sized spans of `data`:
/// `f(chunk_index, span)`.  The last span may be shorter.
pub fn par_chunks_mut<T: Send>(
    data: &mut [T],
    chunk_len: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    if data.is_empty() {
        return;
    }
    let chunk_len = chunk_len.max(1);
    let n = data.len();
    let n_chunks = n.div_ceil(chunk_len);
    let base = SharedSlice::new(data);
    run_chunked(n_chunks, &|i| {
        let r = chunk_range(i, chunk_len, n);
        // SAFETY: chunk_range spans are pairwise disjoint by construction
        // and the backing slice outlives the region (SharedSlice contract).
        let span = unsafe { base.slice_mut(r) };
        f(i, span);
    });
}

/// Raw shared-slice handle for disjoint cross-thread writes.  Safety
/// contract: every index/range is touched by at most one chunk, and the
/// submitting call blocks until all chunks finish.
struct SharedSlice<T> {
    ptr: *mut T,
    len: usize,
}

// SAFETY: the handle is only shared within one parallel region whose
// submitter blocks until every worker checks out, and the safety
// contract above guarantees chunk-disjoint access to `T: Send` elements.
unsafe impl<T: Send> Send for SharedSlice<T> {}
// SAFETY: as for Send — disjointness makes concurrent `&self` use sound.
unsafe impl<T: Send> Sync for SharedSlice<T> {}

impl<T> SharedSlice<T> {
    fn new(data: &mut [T]) -> SharedSlice<T> {
        SharedSlice { ptr: data.as_mut_ptr(), len: data.len() }
    }

    /// # Safety: `i` must be written by exactly one chunk.
    #[allow(clippy::mut_from_ref)]
    unsafe fn get_mut(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len);
        &mut *self.ptr.add(i)
    }

    /// # Safety: ranges across chunks must not overlap.
    #[allow(clippy::mut_from_ref)]
    unsafe fn slice_mut(&self, r: Range<usize>) -> &mut [T] {
        debug_assert!(r.end <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(r.start), r.end - r.start)
    }
}

/// Rows-per-chunk heuristic for row-sharded **disjoint-output** loops:
/// aim for a few chunks per thread for load balance, with a floor so tiny
/// rows don't produce pathological chunk counts.
///
/// NOT for reductions: the returned chunk size scales with [`threads`],
/// so partials produced with it would combine in a thread-count-dependent
/// order.  Reductions must use a fixed chunk constant (see
/// `C3A_GW_CHUNK` in `runtime/interp/ad.rs`) with [`map_chunks`].
pub fn row_chunk(rows: usize, min_rows: usize) -> usize {
    let target = threads() * 4;
    (rows.div_ceil(target)).max(min_rows).max(1)
}

/// Serializes tests/benches that override the global thread count:
/// without it, concurrent test-harness threads race [`set_threads`] and a
/// "single-threaded" parity leg can silently run multi-threaded, making
/// the bit-parity assertion vacuous.
#[doc(hidden)]
pub fn thread_override_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn for_each_chunk_covers_all_indices() {
        let n = 1003;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        for_each_chunk(n, 17, |r| {
            for i in r {
                // Relaxed: per-slot counter; the region's join orders it.
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        // Relaxed: read after the region joined — already synchronized.
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_chunks_orders_partials() {
        // partial sums combined in chunk order equal the sequential sum
        let n = 500usize;
        let parts = map_chunks(n, 13, |r| r.map(|i| i as u64).sum::<u64>());
        assert_eq!(parts.len(), n.div_ceil(13));
        let total: u64 = parts.iter().sum();
        assert_eq!(total, (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn par_chunks_mut_disjoint_writes() {
        let mut data = vec![0usize; 777];
        par_chunks_mut(&mut data, 32, |ci, span| {
            for (k, v) in span.iter_mut().enumerate() {
                *v = ci * 32 + k;
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i);
        }
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let _lock = thread_override_lock();
        let prev = threads();
        let work = |_: ()| -> Vec<f64> {
            map_chunks(97, 8, |r| r.map(|i| ((i as f64) * 0.37).sin()).sum::<f64>())
        };
        set_threads(1);
        let a = work(());
        set_threads(4);
        let b = work(());
        set_threads(prev);
        // bit-for-bit: chunk boundaries and per-chunk order are fixed
        assert_eq!(a, b);
    }

    #[test]
    fn nested_regions_run_inline() {
        let count = AtomicU64::new(0);
        for_each_chunk(8, 1, |_| {
            // nested region must not deadlock on the submit lock
            for_each_chunk(4, 1, |r| {
                // Relaxed: plain tally; the outer region's join orders it.
                count.fetch_add(r.len() as u64, Ordering::Relaxed);
            });
        });
        // Relaxed: read after the region joined — already synchronized.
        assert_eq!(count.load(Ordering::Relaxed), 32);
    }
}
