//! Circulant / block-circulant algebra — the paper's ΔW = C_blk(Δw).
//!
//! Mirrors the L1 Pallas kernel semantics exactly (convolution convention:
//! first *column* of C(w) is w; see python/compile/kernels/ref.py for the
//! note on the paper's first-row convention).  Used for:
//!   * adapter **merging** (Algorithm A2: ΔW columns = Δw ⋆ e_i),
//!   * host-side inference of merged/unmerged adapters (`serve`),
//!   * the paper's §4.1 *rank* measurements of learned kernels,
//!   * the Table 1 operator benchmarks.
//!
//! # Determinism obligations
//!
//! Every matvec shards *output blocks* across the pool (disjoint writes)
//! and keeps the per-block j-then-k accumulation order fixed, so results
//! are bit-identical at any `C3A_THREADS` setting; the spectral
//! accumulate routes through `fft::cmul_acc`, whose SIMD variant is
//! bitwise the scalar loop.  The FFT path and the dense path
//! ([`BlockCirculant::matvec_dense`]) are each deterministic but are
//! *different* rounding sequences — callers pinning bitwise outputs
//! (the interpreter's C3A op pins FFT) must never switch between them.
//! docs/DETERMINISM.md is normative.

use super::fft::{self, Plan, C};
use super::parallel;
use std::cell::RefCell;

/// Work floor (roughly m·n·b) below which the block loops stay sequential.
const PAR_MIN_WORK: usize = 16 * 1024;

thread_local! {
    /// Doubled-kernel scratch for the dense matvec — thread-local because
    /// the block loop is sharded across the pool, and per-call allocation
    /// would break the steady-state allocation budget.
    static DENSE_SCRATCH: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
}

/// Kernels of a block-circular operator: `m × n` blocks, each length `b`.
#[derive(Clone, Debug)]
pub struct BlockCirculant {
    /// Output block count (d_out = m·b).
    pub m: usize,
    /// Input block count (d_in = n·b).
    pub n: usize,
    /// Block (kernel) length.
    pub b: usize,
    /// row-major `m` × `n` × `b`
    pub w: Vec<f64>,
}

impl BlockCirculant {
    /// Wrap `m·n` kernels of length `b` (row-major, panics on mismatch).
    pub fn new(m: usize, n: usize, b: usize, w: Vec<f64>) -> Self {
        assert_eq!(w.len(), m * n * b);
        Self { m, n, b, w }
    }

    /// All-zero operator of the given block structure.
    pub fn zeros(m: usize, n: usize, b: usize) -> Self {
        Self { m, n, b, w: vec![0.0; m * n * b] }
    }

    /// Kernel of block (i, j).
    #[inline]
    pub fn kernel(&self, i: usize, j: usize) -> &[f64] {
        let o = (i * self.n + j) * self.b;
        &self.w[o..o + self.b]
    }

    /// Trainable parameter count: d1·d2/b (paper §3.4).
    pub fn param_count(&self) -> usize {
        self.m * self.n * self.b
    }

    /// Output dimension m·b.
    pub fn d_out(&self) -> usize {
        self.m * self.b
    }

    /// Input dimension n·b.
    pub fn d_in(&self) -> usize {
        self.n * self.b
    }

    /// Δz = C_blk(Δw)·x via per-block FFT (the paper's Eq. 1 + §3.4).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let plan = Plan::new(self.b);
        self.matvec_with(&plan, x)
    }

    /// FFT matvec with a reusable plan.  The per-output-block loop (kernel
    /// FFTs + spectral accumulate + inverse FFT) is sharded across the
    /// substrate pool; each output block is computed identically at any
    /// thread count.
    pub fn matvec_with(&self, plan: &Plan, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.d_in());
        let b = self.b;
        // forward transforms of the n input blocks
        let xf: Vec<Vec<C>> =
            (0..self.n).map(|j| fft::rfft(plan, &x[j * b..(j + 1) * b])).collect();
        let mut out = vec![0.0; self.d_out()];
        let block = |i: usize, out_i: &mut [f64]| {
            let mut acc = vec![(0.0, 0.0); b];
            for j in 0..self.n {
                let wf = fft::rfft(plan, self.kernel(i, j));
                fft::cmul_acc(&mut acc, &wf, &xf[j]);
            }
            let zi = fft::irfft_real(plan, &acc);
            out_i.copy_from_slice(&zi);
        };
        parallel::for_rows(&mut out, b, self.m * self.n * b >= PAR_MIN_WORK, block);
        out
    }

    /// Δz = C_blk(Δw)·x via the dense O(b²)-per-block kernel — no FFT.
    ///
    /// For small blocks the FFT path's constants (three length-b
    /// transforms' worth of complex arithmetic per block pair) dominate
    /// its O(b log b) asymptotics; the dense kernel streams a doubled
    /// kernel buffer contiguously instead and wins below
    /// [`Self::DENSE_CROSSOVER_B`].  Deterministic like every matvec
    /// here, but a *different* rounding sequence than the FFT path —
    /// this is a separate opt-in API precisely so bitwise-pinned callers
    /// (the interpreter's C3A operator) never switch paths implicitly.
    pub fn matvec_dense(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.d_out()];
        self.matvec_dense_into(x, &mut out);
        out
    }

    /// Allocation-free dense matvec (the doubled-kernel scratch is
    /// thread-local).  Output blocks are sharded across the pool; each
    /// output row's c-ascending accumulation is identical at any thread
    /// count, and the SIMD kernel (`simd::circ_rows`, 4 rows per
    /// register with one lane per row) is bitwise the scalar loop.
    pub fn matvec_dense_into(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.d_in());
        assert_eq!(out.len(), self.d_out());
        let b = self.b;
        let block = |i: usize, out_i: &mut [f64]| {
            out_i.fill(0.0);
            DENSE_SCRATCH.with(|cell| {
                let mut wd = cell.borrow_mut();
                wd.clear();
                wd.resize(2 * b, 0.0);
                for j in 0..self.n {
                    let w = self.kernel(i, j);
                    // doubled kernel: wd[r + b - c] == w[(r + b - c) % b]
                    // without the modulo, so row loads are contiguous
                    wd[..b].copy_from_slice(w);
                    wd[b..].copy_from_slice(w);
                    let xj = &x[j * b..(j + 1) * b];
                    #[cfg(feature = "simd")]
                    if crate::substrate::simd::enabled() {
                        crate::substrate::simd::circ_rows(out_i, &wd, xj);
                        continue;
                    }
                    for r in 0..b {
                        let mut acc = 0.0;
                        for (c, &xv) in xj.iter().enumerate() {
                            acc += wd[r + b - c] * xv;
                        }
                        out_i[r] += acc;
                    }
                }
            });
        };
        parallel::for_rows(out, b, self.m * self.n * b * b >= PAR_MIN_WORK, block);
    }

    /// FFT-vs-dense crossover block length for [`Self::matvec_auto`].
    ///
    /// Heuristic, not a contract: on the operator bench the dense kernel
    /// wins for b at or below roughly this size when kernel spectra are
    /// not cached (it skips the per-call kernel FFTs entirely and its
    /// b² inner loop is branch-free and contiguous); with cached spectra
    /// the FFT path catches up around b ≈ 32.  Re-measure with
    /// `bench_operator` (crossover table) when tuning.
    pub const DENSE_CROSSOVER_B: usize = 64;

    /// Heuristic dispatch: the dense kernel at or below
    /// [`Self::DENSE_CROSSOVER_B`], the FFT path above it.  The two
    /// paths round differently — callers that pin bitwise outputs must
    /// call one of them explicitly instead.
    pub fn matvec_auto(&self, x: &[f64]) -> Vec<f64> {
        if self.b <= Self::DENSE_CROSSOVER_B {
            self.matvec_dense(x)
        } else {
            self.matvec(x)
        }
    }

    /// Precompute kernel spectra once; then matvecs skip the per-call
    /// kernel FFTs — the production inference path.
    pub fn prepared(&self) -> PreparedBlockCirculant {
        let plan = Plan::new(self.b);
        let spectra = (0..self.m * self.n)
            .map(|ij| fft::rfft(&plan, &self.w[ij * self.b..(ij + 1) * self.b]))
            .collect();
        PreparedBlockCirculant { m: self.m, n: self.n, b: self.b, plan, spectra }
    }

    /// Materialize the dense ΔW [d_out × d_in], via the paper's
    /// Algorithm A2: column i of ΔW equals Δw ⋆ e_i.
    pub fn materialize(&self) -> Vec<f64> {
        let (d_out, d_in) = (self.d_out(), self.d_in());
        let prepared = self.prepared();
        let mut out = vec![0.0; d_out * d_in];
        let mut e = vec![0.0; d_in];
        for col in 0..d_in {
            e[col] = 1.0;
            let z = prepared.matvec(&e);
            e[col] = 0.0;
            for row in 0..d_out {
                out[row * d_in + col] = z[row];
            }
        }
        out
    }

    /// Ranks of every block C(Δw_ij) via DFT-eigenvalue counting.
    pub fn block_ranks(&self, tol: f64) -> Vec<usize> {
        let plan = Plan::new(self.b);
        (0..self.m * self.n)
            .map(|ij| circulant_rank_with(&plan, &self.w[ij * self.b..(ij + 1) * self.b], tol))
            .collect()
    }
}

/// Spectra-cached operator for the inference hot path.
pub struct PreparedBlockCirculant {
    /// Output block count.
    pub m: usize,
    /// Input block count.
    pub n: usize,
    /// Block length.
    pub b: usize,
    plan: Plan,
    /// `m·n` spectra, each of length b
    spectra: Vec<Vec<C>>,
}

impl PreparedBlockCirculant {
    /// Spectra-cached FFT matvec (allocating wrapper).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.m * self.b];
        self.matvec_into(x, &mut out);
        out
    }

    /// Allocation-free variant used by the bench/serve hot loops.  Output
    /// blocks are sharded across the substrate pool (disjoint writes, so
    /// bit-for-bit identical at any thread count).
    pub fn matvec_into(&self, x: &[f64], out: &mut [f64]) {
        let b = self.b;
        assert_eq!(x.len(), self.n * b);
        assert_eq!(out.len(), self.m * b);
        let xf: Vec<Vec<C>> =
            (0..self.n).map(|j| fft::rfft(&self.plan, &x[j * b..(j + 1) * b])).collect();
        let block = |i: usize, out_i: &mut [f64]| {
            let mut acc = vec![(0.0, 0.0); b];
            for j in 0..self.n {
                fft::cmul_acc(&mut acc, &self.spectra[i * self.n + j], &xf[j]);
            }
            let zi = fft::irfft_real(&self.plan, &acc);
            out_i.copy_from_slice(&zi);
        };
        parallel::for_rows(out, b, self.m * self.n * b >= PAR_MIN_WORK, block);
    }
}

/// Dense circulant matrix of a single kernel: C[r][c] = w[(r-c) mod b].
pub fn circulant_matrix(w: &[f64]) -> Vec<f64> {
    let b = w.len();
    let mut out = vec![0.0; b * b];
    for r in 0..b {
        for c in 0..b {
            out[r * b + c] = w[(r + b - c) % b];
        }
    }
    out
}

/// rank C(w) = #nonzero DFT coefficients (Ingleton 1956; paper §3.2).
pub fn circulant_rank(w: &[f64], tol: f64) -> usize {
    circulant_rank_with(&Plan::new(w.len()), w, tol)
}

/// [`circulant_rank`] with a reusable plan (hot path of `block_ranks`).
pub fn circulant_rank_with(plan: &Plan, w: &[f64], tol: f64) -> usize {
    let spec = fft::rfft(plan, w);
    // Relative tolerance against the true max DFT magnitude.  Flooring the
    // scale at 1.0 would turn `tol` absolute for small-magnitude kernels
    // (e.g. late-training deltas) and under-count their rank.
    let scale = spec.iter().map(|z| (z.0 * z.0 + z.1 * z.1).sqrt()).fold(0.0f64, f64::max);
    if scale <= 0.0 {
        return 0; // zero kernel: rank 0
    }
    spec.iter().filter(|z| (z.0 * z.0 + z.1 * z.1).sqrt() > tol * scale).count()
}

/// Rank of the full ΔW via Gaussian elimination on the materialized matrix
/// (cross-check for `block_ranks`; O(d³), test/analysis use only).
pub fn dense_rank(mat: &[f64], rows: usize, cols: usize, tol: f64) -> usize {
    let mut a = mat.to_vec();
    let mut rank = 0;
    let mut row = 0;
    for col in 0..cols {
        // find pivot
        let mut piv = row;
        let mut best = 0.0;
        for r in row..rows {
            let v = a[r * cols + col].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best <= tol {
            continue;
        }
        if piv != row {
            for c in 0..cols {
                a.swap(row * cols + c, piv * cols + c);
            }
        }
        let p = a[row * cols + col];
        for r in (row + 1)..rows {
            let f = a[r * cols + col] / p;
            if f != 0.0 {
                for c in col..cols {
                    a[r * cols + c] -= f * a[row * cols + c];
                }
            }
        }
        rank += 1;
        row += 1;
        if row == rows {
            break;
        }
    }
    rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prng::Rng;

    fn rand_bc(rng: &mut Rng, m: usize, n: usize, b: usize) -> BlockCirculant {
        BlockCirculant::new(m, n, b, (0..m * n * b).map(|_| rng.normal()).collect())
    }

    #[test]
    fn matvec_matches_materialized() {
        let mut rng = Rng::seed(1);
        for &(m, n, b) in &[(1usize, 1usize, 8usize), (2, 3, 5), (4, 4, 16), (3, 2, 7)] {
            let bc = rand_bc(&mut rng, m, n, b);
            let x: Vec<f64> = (0..n * b).map(|_| rng.normal()).collect();
            let got = bc.matvec(&x);
            let mat = bc.materialize();
            let (d_out, d_in) = (m * b, n * b);
            for r in 0..d_out {
                let want: f64 = (0..d_in).map(|c| mat[r * d_in + c] * x[c]).sum();
                assert!((got[r] - want).abs() < 1e-9, "r={r}");
            }
        }
    }

    #[test]
    fn prepared_matches_unprepared() {
        let mut rng = Rng::seed(2);
        let bc = rand_bc(&mut rng, 3, 2, 12);
        let x: Vec<f64> = (0..24).map(|_| rng.normal()).collect();
        let a = bc.matvec(&x);
        let b = bc.prepared().matvec(&x);
        for (u, v) in a.iter().zip(&b) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn single_block_is_circulant_matrix() {
        let mut rng = Rng::seed(3);
        let w: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let bc = BlockCirculant::new(1, 1, 6, w.clone());
        let mat = bc.materialize();
        let want = circulant_matrix(&w);
        for (a, b) in mat.iter().zip(&want) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn identity_kernel_materializes_identity() {
        let n = 3;
        let b = 4;
        let mut bc = BlockCirculant::zeros(n, n, b);
        for i in 0..n {
            bc.w[(i * n + i) * b] = 1.0;
        }
        let mat = bc.materialize();
        let d = n * b;
        for r in 0..d {
            for c in 0..d {
                let want = if r == c { 1.0 } else { 0.0 };
                assert!((mat[r * d + c] - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn rank_generic_kernel_is_full() {
        let mut rng = Rng::seed(4);
        let w: Vec<f64> = (0..64).map(|_| rng.normal()).collect();
        assert_eq!(circulant_rank(&w, 1e-9), 64);
    }

    #[test]
    fn rank_constant_kernel_is_one() {
        assert_eq!(circulant_rank(&vec![2.5; 16], 1e-9), 1);
    }

    #[test]
    fn rank_is_scale_invariant() {
        // the tolerance is relative to the true max DFT magnitude, so
        // scaling a kernel must not change its measured rank
        let mut rng = Rng::seed(8);
        let w: Vec<f64> = (0..16).map(|_| rng.normal()).collect();
        let r_full = circulant_rank(&w, 1e-9);
        let tiny: Vec<f64> = w.iter().map(|v| v * 1e-12).collect();
        assert_eq!(circulant_rank(&tiny, 1e-9), r_full);
        // and the zero kernel has rank 0, not "everything above 0·tol"
        assert_eq!(circulant_rank(&vec![0.0; 16], 1e-9), 0);
    }

    #[test]
    fn rank_matches_dense_rank() {
        let mut rng = Rng::seed(5);
        for b in [4usize, 8, 12] {
            // random kernel: full rank
            let w: Vec<f64> = (0..b).map(|_| rng.normal()).collect();
            let dft_rank = circulant_rank(&w, 1e-9);
            let mat = circulant_matrix(&w);
            assert_eq!(dft_rank, dense_rank(&mat, b, b, 1e-9));
            // zero-mean kernel: rank b-1
            let mut wz = w.clone();
            let mean: f64 = wz.iter().sum::<f64>() / b as f64;
            for v in wz.iter_mut() {
                *v -= mean;
            }
            let r1 = circulant_rank(&wz, 1e-9);
            let r2 = dense_rank(&circulant_matrix(&wz), b, b, 1e-7);
            assert_eq!(r1, b - 1);
            assert_eq!(r1, r2);
        }
    }

    #[test]
    fn block_rank_can_exceed_param_budget_rank() {
        // The paper's core claim: with d params (b = d), ΔW can be full
        // rank d, while LoRA with the same budget is capped at rank ~1/2.
        let mut rng = Rng::seed(6);
        let d = 32;
        let bc = BlockCirculant::new(1, 1, d, (0..d).map(|_| rng.normal()).collect());
        let mat = bc.materialize();
        assert_eq!(dense_rank(&mat, d, d, 1e-9), d); // full rank from d params
    }

    /// The dense O(b²) path must agree with the FFT path to rounding
    /// headroom at every shape class (it is a different rounding
    /// sequence, so equality is approximate by design).
    #[test]
    fn dense_matvec_matches_fft_path() {
        let mut rng = Rng::seed(9);
        for &(m, n, b) in &[(1usize, 1usize, 1usize), (1, 1, 4), (2, 3, 5), (3, 2, 16), (2, 2, 33)]
        {
            let bc = rand_bc(&mut rng, m, n, b);
            let x: Vec<f64> = (0..n * b).map(|_| rng.normal()).collect();
            let got = bc.matvec_dense(&x);
            let want = bc.matvec(&x);
            for (r, (u, v)) in got.iter().zip(&want).enumerate() {
                assert!((u - v).abs() < 1e-9, "({m},{n},{b}) r={r}: {u} vs {v}");
            }
            // the auto heuristic picks one of the two real paths
            assert_eq!(bc.matvec_auto(&x).len(), got.len());
        }
    }

    /// Dense-path thread parity: the block loop crosses the m·n·b² work
    /// gate and must stay bit-for-bit across thread counts.
    #[test]
    fn dense_matvec_threaded_parity() {
        let _lock = parallel::thread_override_lock();
        let mut rng = Rng::seed(10);
        // 4·4·40·40 = 25600 crosses PAR_MIN_WORK = 16384
        let bc = rand_bc(&mut rng, 4, 4, 40);
        let x: Vec<f64> = (0..bc.d_in()).map(|_| rng.normal()).collect();
        let prev = parallel::threads();
        parallel::set_threads(1);
        let y1 = bc.matvec_dense(&x);
        parallel::set_threads(4);
        let y4 = bc.matvec_dense(&x);
        parallel::set_threads(prev);
        assert_eq!(y1, y4, "dense matvec must be bit-for-bit across thread counts");
    }

    /// Scalar vs SIMD bitwise parity for both the FFT and dense paths,
    /// including a block length with a sub-tile tail.  Vacuous without
    /// `--features simd`; the catalog pin lives in tests/simd_parity.rs.
    #[test]
    fn matvec_simd_bitwise_parity() {
        use crate::substrate::simd;
        let _guard = simd::override_lock();
        let prev = simd::enabled();
        let mut rng = Rng::seed(12);
        for &(m, n, b) in &[(1usize, 1usize, 3usize), (2, 3, 8), (3, 2, 13), (2, 2, 32)] {
            let bc = rand_bc(&mut rng, m, n, b);
            let x: Vec<f64> = (0..n * b).map(|_| rng.normal()).collect();
            let run = |on: bool| {
                simd::set_enabled(on);
                let fft_y = bc.prepared().matvec(&x);
                let dense_y = bc.matvec_dense(&x);
                simd::set_enabled(prev);
                (fft_y, dense_y)
            };
            let (f_scalar, d_scalar) = run(false);
            let (f_simd, d_simd) = run(true);
            assert_eq!(f_scalar, f_simd, "fft path diverged at ({m},{n},{b})");
            assert_eq!(d_scalar, d_simd, "dense path diverged at ({m},{n},{b})");
        }
    }

    #[test]
    fn matvec_into_no_alloc_path_matches() {
        let mut rng = Rng::seed(7);
        let bc = rand_bc(&mut rng, 2, 2, 16).prepared();
        let x: Vec<f64> = (0..32).map(|_| rng.normal()).collect();
        let mut out = vec![0.0; 32];
        bc.matvec_into(&x, &mut out);
        let want = bc.matvec(&x);
        assert_eq!(out, want);
    }
}
