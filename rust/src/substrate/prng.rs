//! Deterministic PRNG: splitmix64 seeding + xoshiro256** core, with
//! normal/uniform samplers.  Owns every random decision at run time:
//! adapter initialization (incl. the paper's Fig. 3 schemes), VeRA's frozen
//! projections, synthetic dataset generation, and shuffling — so every
//! experiment is reproducible from a single seed.
//!
//! # Determinism obligations
//!
//! Draw sequences are part of the bit-determinism contract
//! (docs/DETERMINISM.md): a given seed must produce the same byte-for-byte
//! stream on every platform and at every thread count.  Never sample from
//! a shared `Rng` inside parallel code — fork per-unit streams first
//! ([`Rng::fork`]) so the consumption order is schedule-independent.

/// FNV-1a offset basis (the empty-input hash / fold seed).
pub const FNV1A_OFFSET: u64 = 0xcbf29ce484222325;

/// Fold more bytes into a running FNV-1a hash (start from
/// [`FNV1A_OFFSET`]).  Shared by the seed-derivation hash, the replay
/// trace hash, and the adapter store's content checksum.
pub fn fnv1a_fold(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// FNV-1a over a byte slice.
pub fn fnv1a_bytes(bytes: &[u8]) -> u64 {
    fnv1a_fold(FNV1A_OFFSET, bytes)
}

/// FNV-1a over a string — the shared seed-derivation hash (decorrelates
/// per-name RNG streams for tasks, models, etc.).
pub fn fnv1a(s: &str) -> u64 {
    fnv1a_bytes(s.as_bytes())
}

/// xoshiro256** with splitmix64 initialization.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator: four splitmix64 draws initialize the
    /// xoshiro256** state, so nearby seeds still give decorrelated streams.
    pub fn seed(seed: u64) -> Self {
        let mut sm = seed;
        let s =
            [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)];
        Self { s, spare: None }
    }

    /// Derive an independent stream (for per-task / per-run seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::seed(self.next_u64() ^ tag.wrapping_mul(0xd1342543de82ef95))
    }

    /// Next raw 64-bit draw (xoshiro256** update).
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // multiply-shift; bias negligible for n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// `n` draws from N(0, std²), rounded to f32 (parameter init).
    pub fn normal_vec(&mut self, n: usize, std: f64) -> Vec<f32> {
        (0..n).map(|_| (self.normal() * std) as f32).collect()
    }

    /// `n` uniform draws from [lo, hi), rounded to f32 (parameter init).
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f32> {
        (0..n).map(|_| self.range(lo, hi) as f32).collect()
    }

    /// Fisher–Yates shuffle of indices 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            idx.swap(i, j);
        }
        idx
    }

    /// Sample k distinct indices from 0..n (k <= n).
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut p = self.permutation(n);
        p.truncate(k);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::seed(42);
        let mut b = Rng::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_moments() {
        let mut rng = Rng::seed(7);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seed(8);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let skew = xs.iter().map(|x| (x - mean).powi(3)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 1e-2);
        assert!((var - 1.0).abs() < 2e-2);
        assert!(skew.abs() < 5e-2);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::seed(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut rng = Rng::seed(10);
        let p = rng.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_decorrelates() {
        let mut rng = Rng::seed(11);
        let mut f1 = rng.fork(1);
        let mut f2 = rng.fork(2);
        let same = (0..64).filter(|_| f1.next_u64() == f2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
