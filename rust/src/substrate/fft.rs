//! Complex FFT: iterative radix-2 with Bluestein fallback for arbitrary n.
//!
//! This is the rust-side realization of the paper's cuFFT dependency
//! (§3.5): circulant matvecs, rank analysis, and adapter merging all run
//! through here.  Real-input convenience wrappers operate on interleaved
//! `(re, im)` slices to stay allocation-free on the hot path.
//!
//! # Determinism obligations
//!
//! A transform's result is a function of its input and `Plan::n` alone —
//! never of the thread count, the `simd` feature, or the `C3A_SIMD`
//! switch (docs/DETERMINISM.md is normative).  Concretely: twiddles are
//! computed once at plan build and only ever *copied* (the per-stage
//! SIMD tables are copies of the scalar table, not recomputations);
//! butterflies and pointwise products are elementwise, so the SIMD
//! kernels in [`crate::substrate::simd`] replay the scalar op order per
//! element exactly; and the `cmul_*` helpers below are the single
//! dispatch point every spectral accumulate in the crate goes through.

use std::cell::RefCell;
use std::f64::consts::PI;

/// A complex number as (re, im) — kept trivially copyable.
pub type C = (f64, f64);

/// Complex addition (componentwise).
#[inline]
pub fn c_add(a: C, b: C) -> C {
    (a.0 + b.0, a.1 + b.1)
}

/// Complex subtraction (componentwise).
#[inline]
pub fn c_sub(a: C, b: C) -> C {
    (a.0 - b.0, a.1 - b.1)
}

/// Complex multiplication.  This exact operation sequence — two products
/// and one subtraction for the real part, two products and one addition
/// for the imaginary part, no FMA — is the contract the SIMD kernels
/// reproduce bitwise; see `simd::cmul2`.
#[inline]
pub fn c_mul(a: C, b: C) -> C {
    (a.0 * b.0 - a.1 * b.1, a.0 * b.1 + a.1 * b.0)
}

/// Pointwise multiply-accumulate `acc[k] += a[k]·b[k]` over equal-length
/// complex slices — the block-circulant spectral accumulate, and the
/// single dispatch point for its SIMD variant.  Both paths are bitwise
/// identical: bins are independent lanes and each bin keeps the scalar
/// product/sum order (docs/DETERMINISM.md § SIMD).
pub fn cmul_acc(acc: &mut [C], a: &[C], b: &[C]) {
    debug_assert!(acc.len() == a.len() && a.len() == b.len());
    #[cfg(feature = "simd")]
    if crate::substrate::simd::enabled() {
        crate::substrate::simd::cmul_acc(acc, a, b);
        return;
    }
    for k in 0..acc.len() {
        let p = c_mul(a[k], b[k]);
        acc[k].0 += p.0;
        acc[k].1 += p.1;
    }
}

/// Pointwise multiply `out[k] = a[k]·b[k]` into a disjoint output slice
/// (Bluestein's chirp products); SIMD-dispatched like [`cmul_acc`].
pub fn cmul_into(out: &mut [C], a: &[C], b: &[C]) {
    debug_assert!(out.len() == a.len() && a.len() == b.len());
    #[cfg(feature = "simd")]
    if crate::substrate::simd::enabled() {
        crate::substrate::simd::cmul_into(out, a, b);
        return;
    }
    for k in 0..out.len() {
        out[k] = c_mul(a[k], b[k]);
    }
}

/// In-place pointwise multiply `x[k] = x[k]·y[k]` (convolution-theorem
/// products); SIMD-dispatched like [`cmul_acc`].
pub fn cmul_inplace(x: &mut [C], y: &[C]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(feature = "simd")]
    if crate::substrate::simd::enabled() {
        crate::substrate::simd::cmul_inplace(x, y);
        return;
    }
    for k in 0..x.len() {
        x[k] = c_mul(x[k], y[k]);
    }
}

/// Twiddle-factor table for a radix-2 FFT of size `n` (power of two).
pub struct Plan {
    /// Transform size this plan was built for.
    pub n: usize,
    /// twiddles[k] = exp(-2πik/n) for k < n/2
    twiddles: Vec<C>,
    /// bit-reversal permutation
    rev: Vec<u32>,
    /// Bluestein scratch (None when n is a power of two)
    bluestein: Option<Bluestein>,
    /// Per-stage contiguous twiddle tables for the SIMD butterflies:
    /// `stage_tw[s][k] = twiddles[k · step]` for the stage with
    /// `len = 2^(s+1)` — copies of the scalar table (bit-identical
    /// factors), laid out unit-stride so the vector loads are contiguous.
    #[cfg(feature = "simd")]
    stage_tw: Vec<Vec<C>>,
}

struct Bluestein {
    /// padded power-of-two size m >= 2n-1
    m: usize,
    /// chirp[k] = exp(-iπk²/n), k < n
    chirp: Vec<C>,
    /// FFT_m of the zero-padded conjugate chirp
    b_hat: Vec<C>,
    inner: Box<Plan>,
}

impl Plan {
    /// Build a plan for any n >= 1.
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        if n.is_power_of_two() {
            let mut twiddles = Vec::with_capacity(n / 2);
            for k in 0..n / 2 {
                let ang = -2.0 * PI * (k as f64) / (n as f64);
                twiddles.push((ang.cos(), ang.sin()));
            }
            let bits = n.trailing_zeros();
            let rev = (0..n as u32)
                .map(|i| if bits == 0 { 0 } else { i.reverse_bits() >> (32 - bits) })
                .collect();
            #[cfg(feature = "simd")]
            let stage_tw = {
                let mut tables = Vec::new();
                let mut len = 2;
                while len <= n {
                    let (half, step) = (len / 2, n / len);
                    tables.push((0..half).map(|k| twiddles[k * step]).collect());
                    len <<= 1;
                }
                tables
            };
            Plan {
                n,
                twiddles,
                rev,
                bluestein: None,
                #[cfg(feature = "simd")]
                stage_tw,
            }
        } else {
            let m = (2 * n - 1).next_power_of_two();
            let mut chirp = Vec::with_capacity(n);
            for k in 0..n {
                // k² mod 2n keeps the angle argument bounded (exact for integer k)
                let kk = ((k as u128 * k as u128) % (2 * n as u128)) as f64;
                let ang = -PI * kk / (n as f64);
                chirp.push((ang.cos(), ang.sin()));
            }
            let inner = Box::new(Plan::new(m));
            let mut b = vec![(0.0, 0.0); m];
            b[0] = (chirp[0].0, -chirp[0].1);
            for k in 1..n {
                let conj = (chirp[k].0, -chirp[k].1);
                b[k] = conj;
                b[m - k] = conj;
            }
            inner.fft_in_place(&mut b);
            Plan {
                n,
                twiddles: Vec::new(),
                rev: Vec::new(),
                bluestein: Some(Bluestein { m, chirp, b_hat: b, inner }),
                #[cfg(feature = "simd")]
                stage_tw: Vec::new(),
            }
        }
    }

    /// Forward DFT in place: X[k] = Σ x[j]·exp(-2πijk/n).
    pub fn fft_in_place(&self, data: &mut [C]) {
        assert_eq!(data.len(), self.n);
        match &self.bluestein {
            None => self.radix2(data),
            Some(bs) => self.bluestein_fft(bs, data),
        }
    }

    /// Inverse DFT in place (normalized by 1/n).
    pub fn ifft_in_place(&self, data: &mut [C]) {
        // conj -> fft -> conj, scale
        for z in data.iter_mut() {
            z.1 = -z.1;
        }
        self.fft_in_place(data);
        let s = 1.0 / self.n as f64;
        for z in data.iter_mut() {
            *z = (z.0 * s, -z.1 * s);
        }
    }

    fn radix2(&self, data: &mut [C]) {
        let n = self.n;
        for i in 0..n {
            let j = self.rev[i] as usize;
            if i < j {
                data.swap(i, j);
            }
        }
        #[cfg(feature = "simd")]
        if crate::substrate::simd::enabled() {
            self.radix2_stages_simd(data);
            return;
        }
        let mut len = 2;
        while len <= n {
            let half = len / 2;
            let step = n / len;
            let mut i = 0;
            while i < n {
                for k in 0..half {
                    let w = self.twiddles[k * step];
                    let u = data[i + k];
                    let t = c_mul(w, data[i + k + half]);
                    data[i + k] = c_add(u, t);
                    data[i + k + half] = c_sub(u, t);
                }
                i += len;
            }
            len <<= 1;
        }
    }

    /// Post-permutation stage loop with `simd::butterfly_stage` on every
    /// stage with half ≥ 2 bins; the len=2 stage (half = 1, only 1/log₂n
    /// of the work) keeps the scalar loop.  Twiddles come from the
    /// per-stage tables copied out of `twiddles` at plan build, and the
    /// len=2 stage performs the full `w·v` multiply exactly like scalar
    /// (never a shortcut add) so non-finite inputs propagate identically.
    #[cfg(feature = "simd")]
    fn radix2_stages_simd(&self, data: &mut [C]) {
        let n = self.n;
        let mut len = 2;
        let mut stage = 0;
        while len <= n {
            if len / 2 >= 2 {
                crate::substrate::simd::butterfly_stage(data, len, &self.stage_tw[stage]);
            } else {
                let mut i = 0;
                while i < n {
                    let u = data[i];
                    let t = c_mul(self.twiddles[0], data[i + 1]);
                    data[i] = c_add(u, t);
                    data[i + 1] = c_sub(u, t);
                    i += 2;
                }
            }
            len <<= 1;
            stage += 1;
        }
    }

    fn bluestein_fft(&self, bs: &Bluestein, data: &mut [C]) {
        let n = self.n;
        // Padded work buffer comes from a per-thread arena: Bluestein sits
        // on the steady-state replay hot path (C3A blocks of non-pow2
        // size), where a fresh `vec![...; m]` per transform would be the
        // dominant allocation.  Safe against reentrancy because the inner
        // plan is always a power of two (radix-2 path, never back here).
        BLUESTEIN_SCRATCH.with(|cell| {
            let mut buf = cell.borrow_mut();
            buf.clear();
            buf.resize(bs.m, (0.0, 0.0));
            let a = &mut buf[..];
            cmul_into(&mut a[..n], &data[..n], &bs.chirp);
            bs.inner.fft_in_place(a);
            cmul_inplace(a, &bs.b_hat);
            bs.inner.ifft_in_place(a);
            cmul_into(data, &a[..n], &bs.chirp);
        });
    }
}

thread_local! {
    /// Per-thread Bluestein work buffer (see [`Plan::bluestein_fft`]).
    /// Thread-local rather than plan-owned because one `Plan` is shared
    /// immutably across the substrate worker pool.
    static BLUESTEIN_SCRATCH: RefCell<Vec<C>> = const { RefCell::new(Vec::new()) };
}

/// Forward DFT of a real signal; returns complex spectrum.
pub fn rfft(plan: &Plan, x: &[f64]) -> Vec<C> {
    let mut buf: Vec<C> = x.iter().map(|&v| (v, 0.0)).collect();
    plan.fft_in_place(&mut buf);
    buf
}

/// Forward DFT of a real f32 signal into a caller-owned buffer — the
/// allocation-free entry point for the interpreter's replay hot path.
/// Bit-identical to `rfft(plan, &x.map(f64::from))`: the f32 -> f64
/// widening is exact, so staging through an intermediate f64 vector (as
/// [`rfft`] callers used to) changes nothing.
pub fn rfft_f32_into(plan: &Plan, x: &[f32], out: &mut Vec<C>) {
    out.clear();
    out.extend(x.iter().map(|&v| (v as f64, 0.0)));
    plan.fft_in_place(out);
}

/// Inverse DFT, returning only the real part.
pub fn irfft_real(plan: &Plan, spec: &[C]) -> Vec<f64> {
    let mut buf = spec.to_vec();
    plan.ifft_in_place(&mut buf);
    buf.into_iter().map(|z| z.0).collect()
}

/// Inverse DFT into a caller-owned complex buffer (real parts are read
/// out of `out[k].0` by the caller).  Same numerics as [`irfft_real`]
/// minus its two output allocations.
pub fn irfft_into(plan: &Plan, spec: &[C], out: &mut Vec<C>) {
    out.clear();
    out.extend_from_slice(spec);
    plan.ifft_in_place(out);
}

/// Naive O(n²) DFT — the test oracle for the fast paths.
pub fn dft_naive(x: &[C]) -> Vec<C> {
    let n = x.len();
    (0..n)
        .map(|k| {
            let mut acc = (0.0, 0.0);
            for (j, &v) in x.iter().enumerate() {
                let ang = -2.0 * PI * (j as f64) * (k as f64) / (n as f64);
                acc = c_add(acc, c_mul(v, (ang.cos(), ang.sin())));
            }
            acc
        })
        .collect()
}

/// Circular convolution of two real signals via FFT (any length).
pub fn circular_convolve(a: &[f64], b: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len());
    let plan = Plan::new(a.len());
    circular_convolve_with(&plan, a, b)
}

/// Same, reusing a prebuilt plan (hot path).
pub fn circular_convolve_with(plan: &Plan, a: &[f64], b: &[f64]) -> Vec<f64> {
    let mut fa = rfft(plan, a);
    let fb = rfft(plan, b);
    cmul_inplace(&mut fa, &fb);
    irfft_real(plan, &fa)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::simd;

    fn assert_close(a: &[C], b: &[C], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((x.0 - y.0).abs() < tol && (x.1 - y.1).abs() < tol, "{x:?} vs {y:?}");
        }
    }

    /// Runs a property-test body under BOTH kernel configurations —
    /// scalar and, when compiled with `--features simd`, the SIMD
    /// microkernels (same body, same budgets).  Without the feature the
    /// second pass degenerates to a scalar re-run, which keeps the test
    /// list identical across configurations.
    macro_rules! both_configs {
        ($(#[doc = $doc:expr])* $name:ident, $body:block) => {
            $(#[doc = $doc])*
            #[test]
            fn $name() {
                let _guard = simd::override_lock();
                let prev = simd::enabled();
                for on in [false, true] {
                    simd::set_enabled(on);
                    let res = std::panic::catch_unwind(|| $body);
                    simd::set_enabled(prev);
                    if let Err(e) = res {
                        eprintln!("{}: failed with simd enabled = {on}", stringify!($name));
                        std::panic::resume_unwind(e);
                    }
                }
            }
        };
    }

    both_configs!(radix2_matches_naive, {
        for n in [1usize, 2, 4, 8, 64, 256] {
            let x: Vec<C> =
                (0..n).map(|i| ((i as f64 * 0.7).sin(), (i as f64 * 0.3).cos())).collect();
            let want = dft_naive(&x);
            let plan = Plan::new(n);
            let mut got = x.clone();
            plan.fft_in_place(&mut got);
            assert_close(&got, &want, 1e-9 * (n as f64 + 1.0));
        }
    });

    both_configs!(bluestein_matches_naive, {
        for n in [3usize, 5, 6, 7, 12, 48, 100, 192, 320, 768] {
            let x: Vec<C> =
                (0..n).map(|i| ((i as f64 * 1.1).sin(), (i as f64 * 0.5).sin())).collect();
            let want = dft_naive(&x);
            let plan = Plan::new(n);
            let mut got = x.clone();
            plan.fft_in_place(&mut got);
            assert_close(&got, &want, 1e-8 * (n as f64 + 1.0));
        }
    });

    both_configs!(ifft_inverts_fft, {
        for n in [4usize, 7, 16, 100] {
            let x: Vec<C> = (0..n).map(|i| (i as f64, -(i as f64) * 0.5)).collect();
            let plan = Plan::new(n);
            let mut y = x.clone();
            plan.fft_in_place(&mut y);
            plan.ifft_in_place(&mut y);
            assert_close(&y, &x, 1e-8 * (n as f64 + 1.0));
        }
    });

    /// The SIMD transforms must be BITWISE the scalar ones — not merely
    /// close — at radix-2 and Bluestein sizes, forward and inverse
    /// (docs/DETERMINISM.md § SIMD; the full-catalog pin lives in
    /// tests/simd_parity.rs).  Vacuous without `--features simd` (both
    /// legs run scalar), and kept in the test list so the names match.
    #[test]
    fn simd_transforms_bitwise_match_scalar() {
        let _guard = simd::override_lock();
        let prev = simd::enabled();
        for (i, &n) in [1usize, 2, 4, 8, 13, 100, 256, 768, 1024].iter().enumerate() {
            let x = rand_signal(n, 0x5eed ^ ((i as u64) << 21));
            let plan = Plan::new(n);
            let run = |on: bool| {
                simd::set_enabled(on);
                let mut fwd = x.clone();
                plan.fft_in_place(&mut fwd);
                let mut inv = fwd.clone();
                plan.ifft_in_place(&mut inv);
                simd::set_enabled(prev);
                (fwd, inv)
            };
            let (f_scalar, i_scalar) = run(false);
            let (f_simd, i_simd) = run(true);
            assert_eq!(f_scalar, f_simd, "forward fft diverged at n={n}");
            assert_eq!(i_scalar, i_simd, "inverse fft diverged at n={n}");
        }
    }

    /// Seeded random signals in [-0.5, 0.5).
    fn rand_signal(n: usize, seed: u64) -> Vec<C> {
        let mut s = seed | 1;
        let mut next = move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (s as f64 / u64::MAX as f64) - 0.5
        };
        (0..n).map(|_| (next(), next())).collect()
    }

    /// Explicit per-element round-trip budget for a size-n transform.
    ///
    /// Radix-2 loses O(eps·log2 n) relative accuracy; Bluestein routes
    /// through three transforms of size m ≈ 2n plus two chirp products, so
    /// its constant is larger.  The budget is pinned here (and documented
    /// in README § Differential testing) so any future normalization or
    /// twiddle drift fails loudly instead of shifting silently.
    fn roundtrip_budget(n: usize, max_abs: f64) -> f64 {
        let stages = (n as f64).log2().max(1.0);
        // Bluestein routes through padded size-m transforms whose
        // intermediates carry ~m× the signal magnitude, so its constant
        // gets the extra headroom explicitly rather than silently.
        let bluestein = if n.is_power_of_two() { 1.0 } else { 32.0 };
        2e-14 * stages * bluestein * max_abs.max(1.0)
    }

    // Randomized ifft∘fft round-trips at the block sizes the C3A operator
    // actually sees: degenerate (1, 2), odd/Bluestein (3, 7, 13, 101),
    // and large power-of-two (1024, 4096).
    both_configs!(ifft_roundtrip_randomized_sizes_and_budget, {
        for (i, &n) in [1usize, 2, 3, 7, 13, 101, 1024, 4096].iter().enumerate() {
            let x = rand_signal(n, 0x9e3779b97f4a7c15 ^ ((i as u64) << 17));
            let max_abs = x.iter().map(|z| z.0.abs().max(z.1.abs())).fold(0.0, f64::max);
            let plan = Plan::new(n);
            let mut y = x.clone();
            plan.fft_in_place(&mut y);
            plan.ifft_in_place(&mut y);
            assert_close(&y, &x, roundtrip_budget(n, max_abs));
        }
    });

    // The real-signal wrappers (the substrate's actual hot path) must
    // also round-trip: irfft_real(rfft(x)) == x under the same budget.
    both_configs!(rfft_irfft_real_roundtrip, {
        for (i, &n) in [1usize, 2, 5, 12, 64, 2048].iter().enumerate() {
            let x: Vec<f64> = rand_signal(n, 0xabcdef ^ ((i as u64) << 9))
                .into_iter()
                .map(|z| z.0)
                .collect();
            let max_abs = x.iter().map(|v| v.abs()).fold(0.0, f64::max);
            let plan = Plan::new(n);
            let back = irfft_real(&plan, &rfft(&plan, &x));
            let tol = roundtrip_budget(n, max_abs);
            for (k, (a, b)) in back.iter().zip(x.iter()).enumerate() {
                assert!((a - b).abs() < tol, "n={n} k={k}: {a} vs {b} (tol {tol})");
            }
        }
    });

    /// DC normalization pin: the mean of a signal must survive a
    /// round-trip exactly to budget at every size class (this is where a
    /// 1/n-vs-1/√n scaling mistake shows up first).
    #[test]
    fn roundtrip_preserves_dc_component() {
        for n in [1usize, 2, 9, 256] {
            let x = vec![(1.0, 0.0); n];
            let plan = Plan::new(n);
            let mut y = x.clone();
            plan.fft_in_place(&mut y);
            // spectrum of a constant: X[0] = n, the rest ~0
            assert!((y[0].0 - n as f64).abs() < 1e-10 * n as f64, "n={n}: X[0]={}", y[0].0);
            plan.ifft_in_place(&mut y);
            assert_close(&y, &x, roundtrip_budget(n, 1.0));
        }
    }

    #[test]
    fn parseval_energy_preserved() {
        let n = 128;
        let x: Vec<C> = (0..n).map(|i| ((i as f64).sin(), 0.0)).collect();
        let e_time: f64 = x.iter().map(|z| z.0 * z.0 + z.1 * z.1).sum();
        let plan = Plan::new(n);
        let mut y = x;
        plan.fft_in_place(&mut y);
        let e_freq: f64 = y.iter().map(|z| z.0 * z.0 + z.1 * z.1).sum::<f64>() / n as f64;
        assert!((e_time - e_freq).abs() < 1e-8);
    }

    both_configs!(convolution_theorem_vs_direct, {
        // property-style: seeded sweep over sizes incl non-pow2
        let mut seed = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            seed ^= seed << 13;
            seed ^= seed >> 7;
            seed ^= seed << 17;
            (seed as f64 / u64::MAX as f64) - 0.5
        };
        for n in [1usize, 2, 3, 8, 13, 32, 60] {
            let a: Vec<f64> = (0..n).map(|_| next()).collect();
            let b: Vec<f64> = (0..n).map(|_| next()).collect();
            let got = circular_convolve(&a, &b);
            for t in 0..n {
                let mut want = 0.0;
                for tau in 0..n {
                    want += a[tau] * b[(t + n - tau) % n];
                }
                assert!((got[t] - want).abs() < 1e-9, "n={n} t={t}");
            }
        }
    });

    // The allocation-free `_into` entry points must be bit-for-bit
    // identical to the allocating paths (the replay arena depends on it),
    // at radix-2 and Bluestein sizes.
    both_configs!(into_variants_match_allocating_paths, {
        for n in [1usize, 2, 7, 13, 16, 100] {
            let x: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.31).sin()).collect();
            let plan = Plan::new(n);
            let xf64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
            let want = rfft(&plan, &xf64);
            let mut got = vec![(9.9, 9.9); 3]; // dirty buffer: must be fully overwritten
            rfft_f32_into(&plan, &x, &mut got);
            assert_eq!(got, want, "rfft_f32_into diverged at n={n}");
            let back_want = irfft_real(&plan, &want);
            let mut back = Vec::new();
            irfft_into(&plan, &want, &mut back);
            assert_eq!(back.len(), back_want.len());
            for (k, (z, w)) in back.iter().zip(back_want.iter()).enumerate() {
                assert!(z.0 == *w, "irfft_into diverged at n={n} k={k}: {} vs {w}", z.0);
            }
        }
    });

    #[test]
    fn impulse_response() {
        let plan = Plan::new(16);
        let mut x = vec![(0.0, 0.0); 16];
        x[0] = (1.0, 0.0);
        plan.fft_in_place(&mut x);
        for z in &x {
            assert!((z.0 - 1.0).abs() < 1e-12 && z.1.abs() < 1e-12);
        }
    }
}
