//! Minimal JSON parser (no serde offline).  Supports the full JSON value
//! grammar minus exotic escapes; enough for artifacts/manifest.json and
//! results emission.

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value (numbers are f64, objects are ordered maps).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number, held as f64.
    Num(f64),
    /// A string (escapes decoded).
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object; `BTreeMap` keeps key order deterministic.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document; trailing bytes are an error.
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            bail!("trailing data at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array element lookup; `None` for non-arrays or out-of-range.
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    /// Borrow as a string, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value truncated to usize, if this is a `Num`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Borrow as an array slice, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Borrow as an object map, if this is an `Obj`.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Compact serialization (results files).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for results emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Wrap an f64 as a [`Json::Num`].
pub fn num(n: f64) -> Json {
    Json::Num(n)
}

/// Wrap a string as a [`Json::Str`].
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// Wrap a vector as a [`Json::Arr`].
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", c as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos])?;
        Ok(Json::Num(txt.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.pos..])?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "x"}, null], "c": {}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(0).unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().get("b").unwrap().as_str(), Some("x"));
        assert!(v.get("c").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"m":{"x":[1,2.5,"s",true,null]}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if !p.exists() {
            eprintln!("skipping: no manifest (run `make artifacts`)");
            return;
        }
        let text = std::fs::read_to_string(p).unwrap();
        let v = Json::parse(&text).unwrap();
        assert!(v.get("artifacts").unwrap().as_arr().unwrap().len() > 10);
    }
}
