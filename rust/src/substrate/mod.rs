//! From-scratch substrates the coordinator depends on.
//!
//! Nothing here touches XLA; these are the pure-rust building blocks for
//! the paper's evaluation: FFT-based circulant algebra (the operator the
//! paper contributes), exact rank analysis, PRNG for adapter/projection
//! initialization, dense linear algebra for baselines, and the JSON /
//! config parsers (no serde available offline — see DESIGN.md §3).
//!
//! Every numeric routine in this layer is bound by the bit-determinism
//! contract in docs/DETERMINISM.md: same artifact + inputs produce
//! bitwise-identical results at any thread count, and (when the `simd`
//! feature is compiled) with the vector kernels on or off.

pub mod circulant;
pub mod env;
pub mod fft;
pub mod json;
pub mod linalg;
pub mod parallel;
pub mod polynomial;
pub mod prng;
pub mod simd;
pub mod tensor;
pub mod toml;
