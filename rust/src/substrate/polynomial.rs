//! Exact circulant rank via polynomial gcd over the rationals.
//!
//! The paper cites Ingleton (1956): rank C(w) = d − deg(gcd(f_w(x), x^d−1))
//! where f_w is the polynomial with coefficients w.  For integer/rational
//! kernels we can evaluate this *exactly* (i64 rationals with gcd
//! normalization), giving an independent cross-check of the numeric
//! DFT-eigenvalue rank in `circulant.rs`.

/// A rational number kept in lowest terms (i128 to absorb the coefficient
/// growth of the rational Euclid chain; remainders are also content-
/// normalized in `Poly::gcd`, which keeps magnitudes small in practice).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Rat {
    /// Numerator (carries the sign).
    pub num: i128,
    /// Denominator, always > 0.
    pub den: i128,
}

fn gcd_i128(a: i128, b: i128) -> i128 {
    let (mut a, mut b) = (a.abs(), b.abs());
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

impl Rat {
    /// The integer `n` as a rational.
    pub fn int(n: i64) -> Self {
        Self { num: n as i128, den: 1 }
    }

    /// num/den reduced to lowest terms with a positive denominator.
    pub fn new(num: i128, den: i128) -> Self {
        assert!(den != 0);
        let sign = if den < 0 { -1 } else { 1 };
        let g = gcd_i128(num, den).max(1);
        Self { num: sign * num / g, den: sign * den / g }
    }

    /// Whether this is exactly zero.
    pub fn is_zero(self) -> bool {
        self.num == 0
    }

    /// Exact sum.
    pub fn add(self, o: Rat) -> Rat {
        Rat::new(self.num * o.den + o.num * self.den, self.den * o.den)
    }

    /// Exact difference.
    pub fn sub(self, o: Rat) -> Rat {
        Rat::new(self.num * o.den - o.num * self.den, self.den * o.den)
    }

    /// Exact product.
    pub fn mul(self, o: Rat) -> Rat {
        Rat::new(self.num * o.num, self.den * o.den)
    }

    /// Exact quotient; panics on division by zero.
    pub fn div(self, o: Rat) -> Rat {
        assert!(!o.is_zero());
        Rat::new(self.num * o.den, self.den * o.num)
    }
}

/// Dense polynomial over Q; coeffs[i] multiplies x^i.  Always trimmed.
#[derive(Clone, Debug, PartialEq)]
pub struct Poly {
    /// Coefficients, low degree first; trailing zeros trimmed by `new`.
    pub coeffs: Vec<Rat>,
}

impl Poly {
    /// Build from coefficients, trimming trailing zeros (zero keeps one).
    pub fn new(mut coeffs: Vec<Rat>) -> Self {
        while coeffs.len() > 1 && coeffs.last().is_some_and(|c| c.is_zero()) {
            coeffs.pop();
        }
        if coeffs.is_empty() {
            coeffs.push(Rat::int(0));
        }
        Self { coeffs }
    }

    /// Polynomial with the given integer coefficients (low degree first).
    pub fn from_ints(v: &[i64]) -> Self {
        Self::new(v.iter().map(|&n| Rat::int(n)).collect())
    }

    /// The zero polynomial.
    pub fn zero() -> Self {
        Self::from_ints(&[0])
    }

    /// Whether this is the zero polynomial.
    pub fn is_zero(&self) -> bool {
        self.coeffs.len() == 1 && self.coeffs[0].is_zero()
    }

    /// Degree (0 for constants, including zero).
    pub fn degree(&self) -> usize {
        self.coeffs.len() - 1
    }

    /// x^d − 1.
    pub fn xd_minus_1(d: usize) -> Self {
        let mut c = vec![Rat::int(0); d + 1];
        c[0] = Rat::int(-1);
        c[d] = Rat::int(1);
        Self::new(c)
    }

    /// Normalize to a monic polynomial (gcd canonical form).
    pub fn monic(mut self) -> Self {
        if self.is_zero() {
            return self;
        }
        let lead = *self.coeffs.last().unwrap();
        for c in self.coeffs.iter_mut() {
            *c = c.div(lead);
        }
        self
    }

    /// Polynomial remainder self mod other (other nonzero).
    pub fn rem(&self, other: &Poly) -> Poly {
        assert!(!other.is_zero());
        let mut r = self.coeffs.clone();
        let do_ = other.degree();
        let lead = *other.coeffs.last().unwrap();
        while r.len() > do_ && !(r.len() == 1 && r[0].is_zero()) {
            let dr = r.len() - 1;
            if dr < do_ {
                break;
            }
            let f = r[dr].div(lead);
            if !f.is_zero() {
                for i in 0..=do_ {
                    let idx = dr - do_ + i;
                    r[idx] = r[idx].sub(f.mul(other.coeffs[i]));
                }
            }
            r.pop();
            while r.len() > 1 && r.last().is_some_and(|c| c.is_zero()) {
                r.pop();
            }
        }
        Poly::new(r)
    }

    /// Scale so coefficients are coprime integers (gcd is defined up to a
    /// scalar; this bounds coefficient growth along the Euclid chain).
    pub fn normalize_content(mut self) -> Self {
        if self.is_zero() {
            return self;
        }
        let mut den_lcm: i128 = 1;
        for c in &self.coeffs {
            den_lcm = den_lcm / gcd_i128(den_lcm, c.den) * c.den;
        }
        let mut num_gcd: i128 = 0;
        let ints: Vec<i128> = self.coeffs.iter().map(|c| c.num * (den_lcm / c.den)).collect();
        for &v in &ints {
            num_gcd = gcd_i128(num_gcd, v);
        }
        let num_gcd = num_gcd.max(1);
        for (c, &v) in self.coeffs.iter_mut().zip(&ints) {
            *c = Rat { num: v / num_gcd, den: 1 };
        }
        self
    }

    /// Monic gcd via Euclid with content normalization.
    pub fn gcd(a: &Poly, b: &Poly) -> Poly {
        let (mut a, mut b) = (a.clone().normalize_content(), b.clone().normalize_content());
        while !b.is_zero() {
            let r = a.rem(&b).normalize_content();
            a = b;
            b = r;
        }
        a.monic()
    }
}

/// Exact rank of C(w) for an integer kernel (paper §3.2, Ingleton 1956).
pub fn circulant_rank_exact(w: &[i64]) -> usize {
    let d = w.len();
    let f = Poly::from_ints(w);
    if f.is_zero() {
        return 0;
    }
    let g = Poly::gcd(&f, &Poly::xd_minus_1(d));
    d - g.degree()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::circulant;

    #[test]
    fn rat_arithmetic() {
        let a = Rat::new(1, 2);
        let b = Rat::new(1, 3);
        assert_eq!(a.add(b), Rat::new(5, 6));
        assert_eq!(a.mul(b), Rat::new(1, 6));
        assert_eq!(a.sub(b), Rat::new(1, 6));
        assert_eq!(a.div(b), Rat::new(3, 2));
        assert_eq!(Rat::new(-2, -4), Rat::new(1, 2));
        assert_eq!(Rat::new(2, -4), Rat::new(-1, 2));
    }

    #[test]
    fn poly_rem_and_gcd() {
        // (x-1)(x+1) = x² - 1; gcd with (x-1)(x+2) is x-1
        let a = Poly::from_ints(&[-1, 0, 1]);
        let b = Poly::from_ints(&[-2, 1, 1]);
        let g = Poly::gcd(&a, &b);
        assert_eq!(g, Poly::from_ints(&[-1, 1]).monic());
    }

    #[test]
    fn constant_kernel_rank_one() {
        // f = c(1 + x + ... + x^{d-1}); gcd with x^d - 1 has degree d-1
        assert_eq!(circulant_rank_exact(&[3, 3, 3, 3]), 1);
        assert_eq!(circulant_rank_exact(&[1; 8]), 1);
    }

    #[test]
    fn generic_kernel_full_rank() {
        assert_eq!(circulant_rank_exact(&[1, 2, 3, 4, 5]), 5);
        assert_eq!(circulant_rank_exact(&[7, 1, 0, 0, 2, 9]), 6);
    }

    #[test]
    fn alternating_kernel() {
        // [1,-1,1,-1]: f(x) = 1 - x + x² - x³ = (1-x)(1+x²); shares
        // x+1? f(-1)=4≠0... roots of x^4-1 are ±1, ±i; f(1)=0, f(i)=1-i+(-1)...
        // evaluate via the exact routine and cross-check numerically below.
        let w = [1i64, -1, 1, -1];
        let exact = circulant_rank_exact(&w);
        let num = circulant::circulant_rank(&[1.0, -1.0, 1.0, -1.0], 1e-9);
        assert_eq!(exact, num);
    }

    #[test]
    fn exact_matches_numeric_on_random_integer_kernels() {
        use crate::substrate::prng::Rng;
        let mut rng = Rng::seed(99);
        for d in [4usize, 6, 8, 12] {
            for _ in 0..20 {
                // small ints, frequently degenerate
                let w: Vec<i64> = (0..d).map(|_| rng.below(5) as i64 - 2).collect();
                let exact = circulant_rank_exact(&w);
                let wf: Vec<f64> = w.iter().map(|&v| v as f64).collect();
                if wf.iter().all(|&v| v == 0.0) {
                    assert_eq!(exact, 0);
                    continue;
                }
                let num = circulant::circulant_rank(&wf, 1e-9);
                assert_eq!(exact, num, "w={w:?}");
            }
        }
    }

    #[test]
    fn rank_bound_is_d() {
        for d in 2..10usize {
            let w: Vec<i64> = (0..d as i64).collect();
            assert!(circulant_rank_exact(&w) <= d);
        }
    }
}
