//! Portable-SIMD microkernels behind the `simd` cargo feature, plus the
//! process-wide runtime switch (`C3A_SIMD`) that selects them.
//!
//! # Determinism obligations
//!
//! Every kernel in this module is **bitwise identical** to the scalar
//! reference loop it replaces (the normative statement lives in
//! `docs/DETERMINISM.md` § SIMD).  That is only possible because the
//! kernels obey two rules:
//!
//! 1. **Lanes map to independent output elements.**  A vector lane never
//!    participates in another lane's reduction: the matmul vectorizes
//!    across output columns, the matvec and dense circulant across
//!    output rows, the FFT butterflies and spectral accumulates across
//!    frequency bins.  Per output element the sequence of IEEE-754
//!    operations — and therefore every intermediate rounding — is
//!    exactly the scalar path's.
//! 2. **No contraction, no reassociation.**  `a * b + c` stays a rounded
//!    multiply followed by a rounded add (`std::simd` never contracts to
//!    FMA), dot-product-style reductions keep the scalar accumulation
//!    order by putting whole rows in single lanes, and no horizontal
//!    lane sum exists anywhere in this module.
//!
//! The switch: with the feature compiled in, the kernels are ON unless
//! the process started with `C3A_SIMD=0`; [`set_enabled`] flips the
//! choice at runtime (used by `tests/simd_parity.rs` and
//! `benches/bench_interp.rs` to compare both paths inside one process).
//! Without the feature, [`enabled`] is a constant `false`, the kernels
//! are not compiled, and the build's numerics are untouched.

use std::sync::{Mutex, MutexGuard};

/// True when the crate was built with `--features simd` — the kernels
/// exist — independent of the runtime switch.
pub fn available() -> bool {
    cfg!(feature = "simd")
}

#[cfg(feature = "simd")]
fn cell() -> &'static std::sync::atomic::AtomicBool {
    use std::sync::atomic::AtomicBool;
    use std::sync::OnceLock;
    static CELL: OnceLock<AtomicBool> = OnceLock::new();
    CELL.get_or_init(|| AtomicBool::new(crate::substrate::env::simd_enabled()))
}

/// True when the SIMD kernels are compiled in *and* switched on.
/// Constant `false` without the `simd` feature, so every dispatch site
/// folds back to the scalar path at compile time.
#[inline]
pub fn enabled() -> bool {
    #[cfg(feature = "simd")]
    {
        // Relaxed: isolated on/off word; selects bitwise-identical code
        // paths, so even a stale read cannot change results.
        cell().load(std::sync::atomic::Ordering::Relaxed)
    }
    #[cfg(not(feature = "simd"))]
    {
        false
    }
}

/// Flip the process-wide SIMD switch.  Never changes results — the
/// kernels are bitwise identical to the scalar loops — only which code
/// runs.  A no-op without the `simd` feature (the scalar build has
/// nothing to switch to).
pub fn set_enabled(on: bool) {
    // Relaxed: see `enabled` — an isolated switch between bit-identical
    // kernels; no other memory is published through it.
    #[cfg(feature = "simd")]
    cell().store(on, std::sync::atomic::Ordering::Relaxed);
    #[cfg(not(feature = "simd"))]
    let _ = on;
}

/// Serializes tests and benches that toggle [`set_enabled`]: the switch
/// is process-global, so concurrent toggles in one test binary would
/// race each other.  When also overriding thread counts, take
/// `parallel::thread_override_lock` first, then this.
pub fn override_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(feature = "simd")]
pub use kernels::{
    butterfly_stage, circ_rows, cmul_acc, cmul_inplace, cmul_into, matvec_span_f64, mm_row_f32,
    mm_row_f64,
};

#[cfg(feature = "simd")]
mod kernels {
    use crate::substrate::fft::{c_mul, C};
    use std::simd::{f32x8, f64x4, simd_swizzle};

    // The interleaved [re, im, re, im, ...] f64 view of a complex slice
    // relies on `(f64, f64)` putting `.0` at offset 0 and `.1` at
    // offset 8.  Checked against bit patterns of 1.0 / 2.0 so a layout
    // change fails the build, not the numerics.
    const _: () = {
        assert!(std::mem::size_of::<C>() == 16 && std::mem::align_of::<C>() == 8);
        // SAFETY: size/align asserted above; any field-order change trips
        // the bit-pattern assertion below at compile time.
        let bits = unsafe { std::mem::transmute::<C, [u64; 2]>((1.0, 2.0)) };
        assert!(bits[0] == 0x3ff0000000000000 && bits[1] == 0x4000000000000000);
    };

    #[inline(always)]
    fn re_im(z: &[C]) -> &[f64] {
        // SAFETY: layout checked by the const assertion above; the view
        // has twice the length and f64 alignment.
        unsafe { std::slice::from_raw_parts(z.as_ptr().cast::<f64>(), z.len() * 2) }
    }

    #[inline(always)]
    fn re_im_mut(z: &mut [C]) -> &mut [f64] {
        // SAFETY: as `re_im`; the borrow is exclusive.
        unsafe { std::slice::from_raw_parts_mut(z.as_mut_ptr().cast::<f64>(), z.len() * 2) }
    }

    /// Two complex products per register; lanes are `[re0, im0, re1, im1]`.
    /// Per pair this expands to exactly the scalar `fft::c_mul` sequence:
    /// `re = a.0·b.0 + (−(a.1·b.1))` (IEEE addition of a negated operand
    /// *is* subtraction) and `im = a.0·b.1 + a.1·b.0` — same products,
    /// same add order, bitwise the scalar result.
    #[inline(always)]
    fn cmul2(a: f64x4, b: f64x4) -> f64x4 {
        let re = simd_swizzle!(a, [0, 0, 2, 2]);
        let im = simd_swizzle!(a, [1, 1, 3, 3]);
        let sw = simd_swizzle!(b, [1, 0, 3, 2]);
        re * b + im * sw * f64x4::from_array([-1.0, 1.0, -1.0, 1.0])
    }

    /// One output row of the f32 matmul: `crow[j] = Σ_p arow[p]·b[p·n+j]`
    /// with `j` vectorized 8 wide (4 accumulator registers = a 32-column
    /// tile held in registers across the whole `p` loop), `p` strictly
    /// ascending per element, and the scalar path's whole-row
    /// `a == 0.0` skip — bitwise identical to the scalar row loop in
    /// `runtime::interp`'s `mm_into`.
    pub fn mm_row_f32(crow: &mut [f32], arow: &[f32], b: &[f32], n: usize) {
        const W: usize = 8;
        const TILE: usize = 4 * W;
        debug_assert_eq!(crow.len(), n);
        debug_assert_eq!(b.len(), arow.len() * n);
        let mut j = 0;
        while j + TILE <= n {
            let mut c0 = f32x8::splat(0.0);
            let mut c1 = f32x8::splat(0.0);
            let mut c2 = f32x8::splat(0.0);
            let mut c3 = f32x8::splat(0.0);
            for (p, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b[p * n + j..p * n + j + TILE];
                let a = f32x8::splat(av);
                c0 = c0 + a * f32x8::from_slice(&brow[..W]);
                c1 = c1 + a * f32x8::from_slice(&brow[W..2 * W]);
                c2 = c2 + a * f32x8::from_slice(&brow[2 * W..3 * W]);
                c3 = c3 + a * f32x8::from_slice(&brow[3 * W..]);
            }
            c0.copy_to_slice(&mut crow[j..j + W]);
            c1.copy_to_slice(&mut crow[j + W..j + 2 * W]);
            c2.copy_to_slice(&mut crow[j + 2 * W..j + 3 * W]);
            c3.copy_to_slice(&mut crow[j + 3 * W..j + TILE]);
            j += TILE;
        }
        while j + W <= n {
            let mut c0 = f32x8::splat(0.0);
            for (p, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                c0 = c0 + f32x8::splat(av) * f32x8::from_slice(&b[p * n + j..p * n + j + W]);
            }
            c0.copy_to_slice(&mut crow[j..j + W]);
            j += W;
        }
        for jj in j..n {
            let mut acc = 0.0f32;
            for (p, &av) in arow.iter().enumerate() {
                if av != 0.0 {
                    acc += av * b[p * n + jj];
                }
            }
            crow[jj] = acc;
        }
    }

    /// One output row of the f64 matmul (`substrate::linalg::matmul`),
    /// structured exactly like [`mm_row_f32`] with 4-wide f64 lanes.
    pub fn mm_row_f64(crow: &mut [f64], arow: &[f64], b: &[f64], n: usize) {
        const W: usize = 4;
        const TILE: usize = 4 * W;
        debug_assert_eq!(crow.len(), n);
        debug_assert_eq!(b.len(), arow.len() * n);
        let mut j = 0;
        while j + TILE <= n {
            let mut c0 = f64x4::splat(0.0);
            let mut c1 = f64x4::splat(0.0);
            let mut c2 = f64x4::splat(0.0);
            let mut c3 = f64x4::splat(0.0);
            for (p, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b[p * n + j..p * n + j + TILE];
                let a = f64x4::splat(av);
                c0 = c0 + a * f64x4::from_slice(&brow[..W]);
                c1 = c1 + a * f64x4::from_slice(&brow[W..2 * W]);
                c2 = c2 + a * f64x4::from_slice(&brow[2 * W..3 * W]);
                c3 = c3 + a * f64x4::from_slice(&brow[3 * W..]);
            }
            c0.copy_to_slice(&mut crow[j..j + W]);
            c1.copy_to_slice(&mut crow[j + W..j + 2 * W]);
            c2.copy_to_slice(&mut crow[j + 2 * W..j + 3 * W]);
            c3.copy_to_slice(&mut crow[j + 3 * W..j + TILE]);
            j += TILE;
        }
        while j + W <= n {
            let mut c0 = f64x4::splat(0.0);
            for (p, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                c0 = c0 + f64x4::splat(av) * f64x4::from_slice(&b[p * n + j..p * n + j + W]);
            }
            c0.copy_to_slice(&mut crow[j..j + W]);
            j += W;
        }
        for jj in j..n {
            let mut acc = 0.0f64;
            for (p, &av) in arow.iter().enumerate() {
                if av != 0.0 {
                    acc += av * b[p * n + jj];
                }
            }
            crow[jj] = acc;
        }
    }

    /// A span of f64 matvec output rows, 4 rows per register with one
    /// *lane per row*: lane `r` accumulates `y[r] = Σ_c a[r][c]·x[c]`
    /// with `c` strictly ascending, replaying the scalar row dot exactly
    /// — the reduction is never split across lanes.  `base_row` locates
    /// the span inside `a` when the caller shards `y`.
    pub fn matvec_span_f64(y: &mut [f64], a: &[f64], x: &[f64], base_row: usize) {
        let cols = x.len();
        let rows = y.len();
        let mut r = 0;
        while r + 4 <= rows {
            let r0 = (base_row + r) * cols;
            let row0 = &a[r0..r0 + cols];
            let row1 = &a[r0 + cols..r0 + 2 * cols];
            let row2 = &a[r0 + 2 * cols..r0 + 3 * cols];
            let row3 = &a[r0 + 3 * cols..r0 + 4 * cols];
            let mut acc = f64x4::splat(0.0);
            for (c, &xv) in x.iter().enumerate() {
                let col = f64x4::from_array([row0[c], row1[c], row2[c], row3[c]]);
                acc = acc + col * f64x4::splat(xv);
            }
            acc.copy_to_slice(&mut y[r..r + 4]);
            r += 4;
        }
        for rr in r..rows {
            let row = &a[(base_row + rr) * cols..(base_row + rr + 1) * cols];
            let mut acc = 0.0;
            for (v, xv) in row.iter().zip(x.iter()) {
                acc += v * xv;
            }
            y[rr] = acc;
        }
    }

    /// Pointwise complex multiply-accumulate `acc[k] += a[k]·b[k]`, two
    /// bins per register.  Bins are independent lanes, so per bin the
    /// products and both running sums round exactly as the scalar loop
    /// in `fft::cmul_acc`.
    pub fn cmul_acc(acc: &mut [C], a: &[C], b: &[C]) {
        let pairs = acc.len() / 2;
        let (af, bf, accf) = (re_im(a), re_im(b), re_im_mut(acc));
        for k in 0..pairs {
            let o = 4 * k;
            let av = f64x4::from_slice(&af[o..o + 4]);
            let bv = f64x4::from_slice(&bf[o..o + 4]);
            let cur = f64x4::from_slice(&accf[o..o + 4]);
            (cur + cmul2(av, bv)).copy_to_slice(&mut accf[o..o + 4]);
        }
        for i in 2 * pairs..acc.len() {
            let p = c_mul(a[i], b[i]);
            acc[i].0 += p.0;
            acc[i].1 += p.1;
        }
    }

    /// Pointwise complex multiply `out[k] = a[k]·b[k]`, two bins per
    /// register; bitwise the scalar `fft::c_mul` per bin.
    pub fn cmul_into(out: &mut [C], a: &[C], b: &[C]) {
        let pairs = out.len() / 2;
        {
            let (af, bf) = (re_im(a), re_im(b));
            let of = re_im_mut(out);
            for k in 0..pairs {
                let o = 4 * k;
                let av = f64x4::from_slice(&af[o..o + 4]);
                let bv = f64x4::from_slice(&bf[o..o + 4]);
                cmul2(av, bv).copy_to_slice(&mut of[o..o + 4]);
            }
        }
        for i in 2 * pairs..out.len() {
            out[i] = c_mul(a[i], b[i]);
        }
    }

    /// In-place pointwise complex multiply `x[k] = x[k]·y[k]`, two bins
    /// per register; bitwise the scalar `fft::c_mul` per bin.
    pub fn cmul_inplace(x: &mut [C], y: &[C]) {
        let pairs = x.len() / 2;
        {
            let yf = re_im(y);
            let xf = re_im_mut(x);
            for k in 0..pairs {
                let o = 4 * k;
                let xv = f64x4::from_slice(&xf[o..o + 4]);
                let yv = f64x4::from_slice(&yf[o..o + 4]);
                cmul2(xv, yv).copy_to_slice(&mut xf[o..o + 4]);
            }
        }
        for i in 2 * pairs..x.len() {
            x[i] = c_mul(x[i], y[i]);
        }
    }

    /// Every radix-2 butterfly of one FFT stage (`len = 2·half`,
    /// `half ≥ 2`): for each block and bin `k`,
    /// `t = w[k]·data[i+k+half]`, `data[i+k] = u + t`,
    /// `data[i+k+half] = u − t`, two bins per register.  The twiddles in
    /// `tw` are *copies* of the scalar table (never recomputed) and the
    /// per-bin op order matches the scalar stage loop in `fft::Plan`.
    pub fn butterfly_stage(data: &mut [C], len: usize, tw: &[C]) {
        let half = len / 2;
        debug_assert!(half >= 2 && half % 2 == 0, "scalar caller handles the len=2 stage");
        debug_assert_eq!(tw.len(), half);
        let n = data.len();
        let twf = re_im(tw);
        let df = re_im_mut(data);
        let mut i = 0;
        while i < n {
            let (lo, hi) = (2 * i, 2 * (i + half));
            let mut k = 0;
            while k < 2 * half {
                let w = f64x4::from_slice(&twf[k..k + 4]);
                let u = f64x4::from_slice(&df[lo + k..lo + k + 4]);
                let v = f64x4::from_slice(&df[hi + k..hi + k + 4]);
                let t = cmul2(w, v);
                (u + t).copy_to_slice(&mut df[lo + k..lo + k + 4]);
                (u - t).copy_to_slice(&mut df[hi + k..hi + k + 4]);
                k += 4;
            }
            i += len;
        }
    }

    /// Dense circulant block accumulate `z[r] += Σ_c wd[r+b−c]·x[c]`
    /// where `wd` is the doubled kernel (`wd[i] = w[i mod b]`, length
    /// `2b`) and `b = z.len()`.  Four output rows per register, one lane
    /// per row, `c` ascending — each lane replays the scalar dense row
    /// sum in `circulant::matvec_dense_into` exactly.
    pub fn circ_rows(z: &mut [f64], wd: &[f64], x: &[f64]) {
        let b = z.len();
        debug_assert_eq!(wd.len(), 2 * b);
        debug_assert_eq!(x.len(), b);
        let mut r = 0;
        while r + 4 <= b {
            let mut acc = f64x4::splat(0.0);
            for (c, &xv) in x.iter().enumerate() {
                let base = r + b - c;
                let col = f64x4::from_slice(&wd[base..base + 4]);
                acc = acc + col * f64x4::splat(xv);
            }
            let zc = f64x4::from_slice(&z[r..r + 4]);
            (zc + acc).copy_to_slice(&mut z[r..r + 4]);
            r += 4;
        }
        for rr in r..b {
            let mut acc = 0.0;
            for (c, &xv) in x.iter().enumerate() {
                acc += wd[rr + b - c] * xv;
            }
            z[rr] += acc;
        }
    }
}
