//! Single choke point for every `C3A_*` environment switch.
//!
//! Every runtime knob the repo reads from the process environment is
//! declared, documented, and parsed **here** — nowhere else.  The
//! determinism linter enforces this as rule **D4** (`tools/detlint`):
//! any `env::var("C3A_*")` / `set_var("C3A_*")` with a raw string
//! literal outside this module fails `scripts/lint.sh`.  Centralizing
//! the reads buys three things:
//!
//! * **One parsing convention.**  Boolean switches all go through
//!   [`truthy`]: unset or empty means the documented default; a trimmed,
//!   ASCII-case-insensitive `0` / `false` / `off` disables; anything
//!   else enables.  Before this module existed, `C3A_PLAN` trimmed its
//!   value and `C3A_SIMD` did not — two conventions for the same kind of
//!   knob.
//! * **A complete inventory.**  The quick-reference table in
//!   docs/DETERMINISM.md is generated from the constants below by
//!   inspection; a knob that is not listed here does not exist.
//! * **Test hygiene.**  [`ScopedSet`] is the one save/override/restore
//!   guard for tests and benches that must flip a knob process-wide
//!   (it replaced three hand-rolled copies of the same Drop guard).
//!
//! None of these switches may change numerics: every knob here trades
//! wall-clock, output paths, or test scope — the bit-determinism
//! contract (docs/DETERMINISM.md) holds at every setting.

/// `C3A_THREADS` — substrate pool size (see [`super::parallel`]).
/// Default: `available_parallelism()`.  Wall-clock only.
pub const THREADS: &str = "C3A_THREADS";

/// `C3A_PLAN` — execution-plan recording/replay kill switch (see
/// `runtime/plan`).  Default on; `0` rebuilds every call.  Wall-clock
/// only.
pub const PLAN: &str = "C3A_PLAN";

/// `C3A_SIMD` — runtime switch for the vector microkernels when the
/// crate was built with `--features simd` (see [`super::simd`]).
/// Default on; a no-op in scalar builds.  Wall-clock only.
pub const SIMD: &str = "C3A_SIMD";

/// `C3A_HOIST` — version-invariant prefix hoisting in plan replay (see
/// `runtime/plan`).  Default on; `0` recomputes every op on every
/// replay.  A skipped op would have recomputed identical bits from
/// identical inputs, so this is wall-clock only.
pub const HOIST: &str = "C3A_HOIST";

/// `C3A_DIFF_FULL` — widens `tests/differential.rs` from the tiny
/// catalog to the full small-model sweep.  Default off.
pub const DIFF_FULL: &str = "C3A_DIFF_FULL";

/// `C3A_DIFF_REPORT` — divergence-report path written by
/// `tests/differential.rs`.  Default `DIFF_REPORT.txt`.
pub const DIFF_REPORT: &str = "C3A_DIFF_REPORT";

/// `C3A_BENCH_OUT` — report path written by `benches/bench_interp.rs`.
/// Default `BENCH_interp.json`.
pub const BENCH_OUT: &str = "C3A_BENCH_OUT";

/// `C3A_BENCH_SERVE_OUT` — report path written by
/// `benches/bench_serve.rs` and `examples/serve.rs`.  Default
/// `BENCH_serve.json`.
pub const BENCH_SERVE_OUT: &str = "C3A_BENCH_SERVE_OUT";

/// Raw (unparsed, untrimmed) value of a `C3A_*` variable, `None` when
/// unset or not valid UTF-8.  For observability stamps (the bench
/// reports record the operator's literal `C3A_THREADS`) and for
/// [`ScopedSet`]'s save/restore; everything else should use the typed
/// accessors below.
pub fn raw(name: &str) -> Option<String> {
    std::env::var(name).ok()
}

/// The one boolean-parsing convention (rule **D4** rationale): unset or
/// blank → `default`; trimmed, ASCII-case-insensitive `0` / `false` /
/// `off` → `false`; any other value → `true`.
pub fn truthy(name: &str, default: bool) -> bool {
    match raw(name) {
        None => default,
        Some(v) => {
            let t = v.trim();
            if t.is_empty() {
                default
            } else {
                !(t == "0" || t.eq_ignore_ascii_case("false") || t.eq_ignore_ascii_case("off"))
            }
        }
    }
}

/// [`THREADS`] parsed: `Some(n)` for an integer ≥ 1, `None` when unset,
/// unparsable, or zero (callers then fall back to
/// `available_parallelism()` — see `parallel::default_threads`).
pub fn threads() -> Option<usize> {
    raw(THREADS).and_then(|v| v.trim().parse::<usize>().ok()).filter(|&n| n >= 1)
}

/// [`PLAN`]: whether execution-plan recording/replay is enabled
/// (default yes).
pub fn plan_enabled() -> bool {
    truthy(PLAN, true)
}

/// [`SIMD`]: whether the vector microkernels are switched on at process
/// start (default yes; only consulted when built with the feature).
pub fn simd_enabled() -> bool {
    truthy(SIMD, true)
}

/// [`HOIST`]: whether eval-plan replay skips version-invariant ops
/// whose inputs have not changed bitwise (default yes).
pub fn hoist_enabled() -> bool {
    truthy(HOIST, true)
}

/// [`DIFF_FULL`]: whether the differential suite runs the widened
/// sweep (default no).
pub fn diff_full() -> bool {
    truthy(DIFF_FULL, false)
}

/// [`DIFF_REPORT`] or its default path.
pub fn diff_report_path() -> String {
    raw(DIFF_REPORT).unwrap_or_else(|| "DIFF_REPORT.txt".into())
}

/// [`BENCH_OUT`] or its default path.
pub fn bench_out() -> String {
    raw(BENCH_OUT).unwrap_or_else(|| "BENCH_interp.json".into())
}

/// [`BENCH_SERVE_OUT`] or its default path.
pub fn bench_serve_out() -> String {
    raw(BENCH_SERVE_OUT).unwrap_or_else(|| "BENCH_serve.json".into())
}

/// Scoped environment override: saves the prior value on construction,
/// sets the new one, and restores (or removes) on drop — so panics and
/// early returns cannot leak an override into later sessions in the
/// same process.  Callers that toggle process-global knobs from
/// concurrent tests must additionally hold their subsystem's serializer
/// (e.g. `parallel::thread_override_lock`).
pub struct ScopedSet {
    name: &'static str,
    prev: Option<String>,
}

impl ScopedSet {
    /// Override `name` (one of this module's constants) with `value`
    /// until the guard drops.
    pub fn set(name: &'static str, value: &str) -> ScopedSet {
        let prev = raw(name);
        std::env::set_var(name, value);
        ScopedSet { name, prev }
    }
}

impl Drop for ScopedSet {
    fn drop(&mut self) {
        match &self.prev {
            Some(v) => std::env::set_var(self.name, v),
            None => std::env::remove_var(self.name),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // A name no other code reads: these tests mutate the process
    // environment, so they stay off the real knobs entirely.
    const SCRATCH: &str = "C3A_ENV_RS_TEST_SCRATCH";

    #[test]
    fn truthy_convention() {
        let _g = ScopedSet::set(SCRATCH, "1");
        assert!(truthy(SCRATCH, false));
        for off in ["0", "false", "FALSE", "off", " Off ", " 0 "] {
            let _h = ScopedSet::set(SCRATCH, off);
            assert!(!truthy(SCRATCH, true), "{off:?} should disable");
        }
        for on in ["1", "yes", "on", "2", "anything"] {
            let _h = ScopedSet::set(SCRATCH, on);
            assert!(truthy(SCRATCH, false), "{on:?} should enable");
        }
        // blank falls back to the default, either way
        let _h = ScopedSet::set(SCRATCH, "  ");
        assert!(truthy(SCRATCH, true));
        assert!(!truthy(SCRATCH, false));
    }

    #[test]
    fn scoped_set_restores_prior_value() {
        std::env::remove_var(SCRATCH);
        {
            let _g = ScopedSet::set(SCRATCH, "a");
            assert_eq!(raw(SCRATCH).as_deref(), Some("a"));
            {
                let _h = ScopedSet::set(SCRATCH, "b");
                assert_eq!(raw(SCRATCH).as_deref(), Some("b"));
            }
            assert_eq!(raw(SCRATCH).as_deref(), Some("a"));
        }
        assert_eq!(raw(SCRATCH), None);
    }
}
