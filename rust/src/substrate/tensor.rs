//! Shaped host tensors + the C3AT binary container (checkpoints and the
//! python→rust initial-parameter handoff; format spec in
//! python/compile/tensorio.py).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;
use std::sync::Arc;

/// Element type of a [`Tensor`] (the C3AT container carries only these).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    /// 32-bit IEEE 754 float.
    F32,
    /// 32-bit signed integer.
    I32,
}

impl DType {
    /// Wire code used in the C3AT header (0 = f32, 1 = i32).
    pub fn code(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::I32 => 1,
        }
    }

    /// Inverse of [`DType::code`]; errors on unknown codes.
    pub fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => DType::F32,
            1 => DType::I32,
            _ => bail!("unknown dtype code {c}"),
        })
    }
}

/// A host tensor: shape + raw little-endian storage.
/// Equality is bitwise on the stored payload (exact, NaN-safe) — used by
/// session caches to detect unchanged parameters.
///
/// The payload sits behind an `Arc`: tensors are immutable after
/// construction, and the serving layer clones whole `TensorMap`s far more
/// often than it builds them (per-shard registration, upload snapshots,
/// store persistence), so `clone` shares storage instead of deep-copying.
#[derive(Clone, Debug)]
pub struct Tensor {
    /// Element type of the payload.
    pub dtype: DType,
    /// Row-major dimensions; empty for scalars (payload length 1).
    pub shape: Vec<usize>,
    /// f32 storage (bit-cast for i32), shared across clones
    data: Arc<Vec<u32>>,
}

impl PartialEq for Tensor {
    fn eq(&self, other: &Self) -> bool {
        self.dtype == other.dtype
            && self.shape == other.shape
            && (Arc::ptr_eq(&self.data, &other.data) || self.data == other.data)
    }
}

impl Tensor {
    /// Build an f32 tensor; `values.len()` must equal the shape's element
    /// count (1 for scalars).
    pub fn from_f32(shape: Vec<usize>, values: &[f32]) -> Self {
        assert_eq!(values.len(), shape.iter().product::<usize>().max(1));
        let data = Arc::new(values.iter().map(|v| v.to_bits()).collect());
        Self { dtype: DType::F32, shape, data }
    }

    /// Build an i32 tensor; same length rule as [`Tensor::from_f32`].
    pub fn from_i32(shape: Vec<usize>, values: &[i32]) -> Self {
        assert_eq!(values.len(), shape.iter().product::<usize>().max(1));
        let data = Arc::new(values.iter().map(|&v| v as u32).collect());
        Self { dtype: DType::I32, shape, data }
    }

    /// All-zeros f32 tensor of the given shape.
    pub fn zeros_f32(shape: Vec<usize>) -> Self {
        let n = shape.iter().product::<usize>().max(1);
        Self { dtype: DType::F32, shape, data: Arc::new(vec![0u32; n]) }
    }

    /// Raw little-endian payload words (bit-exact view, dtype-agnostic) —
    /// what serializers hash and write so round-trips stay bitwise.
    pub fn bits(&self) -> &[u32] {
        &self.data
    }

    /// Whether two tensors share one payload allocation (clone-sharing
    /// observability; equality is still by value).
    pub fn shares_storage(&self, other: &Self) -> bool {
        Arc::ptr_eq(&self.data, &other.data)
    }

    /// Number of stored elements (1 for scalars).
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the payload is empty (only possible for zero-sized dims).
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Decode the payload as f32 values; panics on dtype mismatch.
    pub fn as_f32(&self) -> Vec<f32> {
        assert_eq!(self.dtype, DType::F32);
        self.data.iter().map(|&b| f32::from_bits(b)).collect()
    }

    /// Decode the payload as i32 values; panics on dtype mismatch.
    pub fn as_i32(&self) -> Vec<i32> {
        assert_eq!(self.dtype, DType::I32);
        self.data.iter().map(|&b| b as i32).collect()
    }

    /// Dimensions as i64 (what the xla crate's reshape wants).
    pub fn dims_i64(&self) -> Vec<i64> {
        self.shape.iter().map(|&d| d as i64).collect()
    }
}

/// Ordered named-tensor container.
pub type TensorMap = BTreeMap<String, Tensor>;

const MAGIC: &[u8; 4] = b"C3AT";

/// Save a tensor map in the C3AT format.
pub fn save<P: AsRef<Path>>(path: P, tensors: &TensorMap) -> Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&1u32.to_le_bytes());
    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (name, t) in tensors {
        let nb = name.as_bytes();
        buf.extend_from_slice(&(nb.len() as u32).to_le_bytes());
        buf.extend_from_slice(nb);
        buf.push(t.dtype.code());
        buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
        for &d in &t.shape {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        for &w in t.data.iter() {
            buf.extend_from_slice(&w.to_le_bytes());
        }
    }
    let tmp = path.as_ref().with_extension("tmp");
    std::fs::File::create(&tmp)?.write_all(&buf)?;
    std::fs::rename(&tmp, path.as_ref())?;
    Ok(())
}

/// Load a C3AT tensor map.
pub fn load<P: AsRef<Path>>(path: P) -> Result<TensorMap> {
    let mut bytes = Vec::new();
    std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?
        .read_to_end(&mut bytes)?;
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
        if *pos + n > bytes.len() {
            bail!("truncated C3AT file");
        }
        let s = &bytes[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    if take(&mut pos, 4)? != MAGIC {
        bail!("bad magic");
    }
    let version = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
    if version != 1 {
        bail!("unsupported version {version}");
    }
    let count = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
    let mut out = TensorMap::new();
    for _ in 0..count {
        let nlen = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        let name = String::from_utf8(take(&mut pos, nlen)?.to_vec())?;
        let dtype = DType::from_code(take(&mut pos, 1)?[0])?;
        let ndim = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize);
        }
        let n = shape.iter().product::<usize>().max(1);
        let raw = take(&mut pos, 4 * n)?;
        let data = raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect();
        out.insert(name, Tensor { dtype, shape, data: Arc::new(data) });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut m = TensorMap::new();
        m.insert("a.w".into(), Tensor::from_f32(vec![2, 3], &[1.0, -2.0, 3.5, 0.0, 1e-8, 7.0]));
        m.insert("b.ids".into(), Tensor::from_i32(vec![4], &[1, -1, 1 << 20, 0]));
        m.insert("scalar".into(), Tensor::from_f32(vec![], &[42.0]));
        let dir = std::env::temp_dir().join("c3a_tensor_test.bin");
        save(&dir, &m).unwrap();
        let back = load(&dir).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back["a.w"].as_f32(), m["a.w"].as_f32());
        assert_eq!(back["a.w"].shape, vec![2, 3]);
        assert_eq!(back["b.ids"].as_i32(), m["b.ids"].as_i32());
        assert_eq!(back["scalar"].as_f32(), vec![42.0]);
    }

    #[test]
    fn reads_python_written_file() {
        // The python build path writes *_init.bin in the same format; if
        // artifacts exist, verify interop.
        let p =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/enc_tiny_init.bin");
        if !p.exists() {
            eprintln!("skipping: {} missing (run `make artifacts`)", p.display());
            return;
        }
        let m = load(&p).unwrap();
        assert!(m.contains_key("embed.tok"));
        let t = &m["embed.tok"];
        assert_eq!(t.dtype, DType::F32);
        assert_eq!(t.shape.len(), 2);
        assert!(t.as_f32().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn clone_shares_storage_and_stays_bitwise_equal() {
        let t = Tensor::from_f32(vec![3], &[1.0, f32::NAN, -0.0]);
        let c = t.clone();
        assert!(t.shares_storage(&c), "clone must share the payload allocation");
        assert_eq!(t, c, "NaN payloads still compare equal bitwise");
        // an equal-by-value rebuild does NOT share storage but IS equal
        let r = Tensor::from_f32(vec![3], &[1.0, f32::NAN, -0.0]);
        assert!(!t.shares_storage(&r));
        assert_eq!(t, r);
        assert_eq!(t.bits(), r.bits());
    }

    #[test]
    fn bad_magic_rejected() {
        let p = std::env::temp_dir().join("c3a_badmagic.bin");
        std::fs::write(&p, b"NOPE").unwrap();
        assert!(load(&p).is_err());
    }
}
