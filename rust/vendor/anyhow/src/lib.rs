//! Minimal offline stand-in for the `anyhow` crate.
//!
//! Implements the subset this workspace uses: an opaque [`Error`] carrying
//! a context chain, the [`Result`] alias, the [`Context`] extension trait
//! for `Result` and `Option`, the `anyhow!` / `bail!` macros, and a blanket
//! `From<E: std::error::Error>` so `?` works on std error types.
//!
//! Formatting matches anyhow's conventions closely enough for this repo:
//! `{}` prints the outermost context, `{:#}` prints the whole chain joined
//! with `: `, and `{:?}` prints the chain with a `Caused by:` section.

use std::fmt;

/// An error with a chain of human-readable context frames.
/// `chain[0]` is the outermost (most recently attached) context.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach an outer context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// Root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for frame in &self.chain[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement std::error::Error, which keeps
// this blanket conversion coherent (same trick as real anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the std source chain as context frames.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("root {}", 42)
    }

    #[test]
    fn context_chain_formats() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root 42");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn std_error_converts() {
        let r: Result<String> = (|| Ok(String::from_utf8(vec![0xff])?))();
        assert!(r.is_err());
    }

    #[test]
    fn with_context_lazy() {
        let ok: std::result::Result<u8, std::io::Error> = Ok(7);
        let v = ok.with_context(|| "never evaluated".to_string()).unwrap();
        assert_eq!(v, 7);
    }
}
